"""Distribution tests that need >1 device run in a subprocess with
--xla_force_host_platform_device_count=8 (tests in-process keep 1 device,
per the dry-run isolation rule)."""

import json
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_runs_and_state_is_sharded():
    out = _run("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import registry
        from repro.parallel import sharding as shd
        from repro.train.optimizer import AdamWConfig
        from repro.train.step import TrainState, make_train_step, train_state_init
        from repro.launch.mesh import make_host_mesh

        cfg = registry.get_reduced("deepseek-7b")
        mesh = make_host_mesh(model_axis=2)      # (4, 2)
        opt = AdamWConfig(lr=1e-3, total_steps=4)
        with mesh:
            state = train_state_init(jax.random.PRNGKey(0), cfg, opt)
            sh = TrainState(
                params=shd.param_sharding_tree(state.params, mesh),
                opt_state={"m": shd.param_sharding_tree(state.opt_state["m"], mesh),
                           "v": shd.param_sharding_tree(state.opt_state["v"], mesh),
                           "count": NamedSharding(mesh, P())},
                step=NamedSharding(mesh, P()))
            state = jax.device_put(state, sh)
            bsh = NamedSharding(mesh, P("data", None))
            step = jax.jit(make_train_step(cfg, opt, grad_accum=2,
                                           grad_sharding=sh.params),
                           in_shardings=(sh, {"tokens": bsh, "labels": bsh}),
                           donate_argnums=(0,))
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size)
            batch = {"tokens": jax.device_put(toks, bsh),
                     "labels": jax.device_put(toks, bsh)}
            losses = []
            for _ in range(4):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
            # a param leaf is genuinely sharded across devices
            wq = state.params["blocks"]["sub0"]["mix"]["wq"]
            nshards = len({d for d in wq.sharding.device_set})
            print(json.dumps({"losses": losses, "nshards": nshards,
                              "finite": bool(m["finite"])}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["finite"]
    assert res["nshards"] > 1
    assert res["losses"][-1] < res["losses"][0]   # tiny model memorises


def test_dryrun_reduced_multipod_semantics():
    """A reduced-config 'production style' lower+compile on an 8-device
    (2,2,2) pod/data/model mesh — the multi-pod axis shards."""
    out = _run("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import registry
        from repro.models import transformer as T
        from repro.parallel import sharding as shd
        from repro.train.optimizer import AdamWConfig
        from repro.train.step import TrainState, abstract_train_state, make_train_step

        cfg = registry.get_reduced("qwen3-moe-235b-a22b")
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        opt = AdamWConfig()
        with mesh:
            state = abstract_train_state(cfg, opt)
            sh = TrainState(
                params=shd.param_sharding_tree(state.params, mesh),
                opt_state={"m": shd.param_sharding_tree(state.opt_state["m"], mesh),
                           "v": shd.param_sharding_tree(state.opt_state["v"], mesh),
                           "count": NamedSharding(mesh, P())},
                step=NamedSharding(mesh, P()))
            bsh = NamedSharding(mesh, P(("pod", "data"), None))
            specs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
            step = jax.jit(make_train_step(cfg, opt, 2, grad_sharding=sh.params),
                           in_shardings=(sh, {k: bsh for k in specs}),
                           donate_argnums=(0,))
            compiled = step.lower(state, specs).compile()
            txt = compiled.as_text()
            has_collectives = any(k in txt for k in
                                  ("all-reduce", "all-gather",
                                   "reduce-scatter", "all-to-all"))
            print(json.dumps({"ok": True,
                              "collectives": has_collectives}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"] and res["collectives"]


def test_data_pipeline_determinism_and_host_sharding():
    from repro.data import SyntheticTokens
    a = SyntheticTokens(1000, 64, 16, seed=7).batch(3)
    b = SyntheticTokens(1000, 64, 16, seed=7).batch(3)
    import numpy as np
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host shard = rows of the same global batch (replacement-host property)
    shard = SyntheticTokens(1000, 64, 16, seed=7, row_start=4, rows=4).batch(3)
    np.testing.assert_array_equal(shard["tokens"], a["tokens"][4:8])
    # different steps differ
    c = SyntheticTokens(1000, 64, 16, seed=7).batch(4)
    assert (a["tokens"] != c["tokens"]).any()


def test_shardmap_moe_matches_gspmd_path():
    out = _run("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models import registry
        from repro.models import moe as MOE

        cfg = registry.get_reduced("qwen3-moe-235b-a22b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
        ref_out, _ = MOE.moe_apply(p, x, cfg=cfg)
        with mesh:
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            esh = NamedSharding(mesh, P("model", None, None))
            ps = {k: jax.device_put(v, esh) if k.startswith("we_")
                  else jax.device_put(v, jax.tree.map(
                      lambda _: NamedSharding(mesh, P()), v))
                  for k, v in p.items()}
            out, aux = jax.jit(lambda p_, x_: MOE.moe_apply_shardmap(
                p_, x_, cfg=cfg, mesh=mesh, dp_axes="data"))(ps, xs)
            g = jax.jit(jax.grad(lambda p_, x_: jnp.sum(
                MOE.moe_apply_shardmap(p_, x_, cfg=cfg, mesh=mesh,
                                       dp_axes="data")[0] ** 2)))(ps, xs)
        err = float(jnp.abs(out - ref_out).max())
        gfin = all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
        print(json.dumps({"err": err, "grad_finite": gfin}))
    """)
    import json as _json
    res = _json.loads(out.strip().splitlines()[-1])
    assert res["err"] < 1e-6
    assert res["grad_finite"]
