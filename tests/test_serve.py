"""Serving engine: generation consistency and bucketing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


@pytest.mark.parametrize("arch", ["deepseek-7b", "rwkv6-1.6b",
                                  "deepseek-v2-lite-16b"])
def test_greedy_generation_matches_manual_decode(arch):
    cfg = registry.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=128)
    rng = np.random.default_rng(0)
    plen = 16
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, plen)))
               for _ in range(2)]
    res = engine.generate(prompts, max_new_tokens=6)

    # manual reference: teacher-forced argmax continuation
    toks = jnp.asarray(prompts)
    caches = T.init_caches(cfg, 2, 128)
    logits, _, caches = T.apply(params, toks, cfg, caches=caches, cache_len=0)
    nxt = jnp.argmax(logits[:, -1], axis=-1)
    manual = [np.asarray(nxt)]
    clen = plen
    for _ in range(5):
        lg, _, caches = T.apply(params, nxt[:, None].astype(jnp.int32), cfg,
                                caches=caches, cache_len=clen)
        nxt = jnp.argmax(lg[:, -1], axis=-1)
        manual.append(np.asarray(nxt))
        clen += 1
    manual = np.stack(manual, axis=1)
    np.testing.assert_array_equal(res.tokens, manual)


def test_generation_is_deterministic_greedy():
    cfg = registry.get_reduced("mistral-nemo-12b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64)
    p = [[1, 2, 3, 4, 5, 6, 7, 8]]
    a = engine.generate(p, max_new_tokens=8).tokens
    b = engine.generate(p, max_new_tokens=8).tokens
    np.testing.assert_array_equal(a, b)
