"""Serving engine: generation consistency, bucketing, and the paged KV
cache (PageAllocator slot storage, exhaustion queueing, preemption)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T
from repro.serve import PageAllocator, ServeEngine


def _solo_tokens(cfg, params, prompt, n, max_len=128):
    """Reference: what this prompt generates alone on a dense engine."""
    solo = ServeEngine(cfg, params, max_batch=1, max_len=max_len,
                      paged=False)
    return solo.generate([prompt], max_new_tokens=n).tokens[0]


@pytest.mark.parametrize("arch", ["deepseek-7b", "rwkv6-1.6b",
                                  "deepseek-v2-lite-16b"])
def test_greedy_generation_matches_manual_decode(arch):
    cfg = registry.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=128)
    rng = np.random.default_rng(0)
    plen = 16
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, plen)))
               for _ in range(2)]
    res = engine.generate(prompts, max_new_tokens=6)

    # manual reference: teacher-forced argmax continuation
    toks = jnp.asarray(prompts)
    caches = T.init_caches(cfg, 2, 128)
    logits, _, caches = T.apply(params, toks, cfg, caches=caches, cache_len=0)
    nxt = jnp.argmax(logits[:, -1], axis=-1)
    manual = [np.asarray(nxt)]
    clen = plen
    for _ in range(5):
        lg, _, caches = T.apply(params, nxt[:, None].astype(jnp.int32), cfg,
                                caches=caches, cache_len=clen)
        nxt = jnp.argmax(lg[:, -1], axis=-1)
        manual.append(np.asarray(nxt))
        clen += 1
    manual = np.stack(manual, axis=1)
    np.testing.assert_array_equal(res.tokens, manual)


def test_generation_is_deterministic_greedy():
    cfg = registry.get_reduced("mistral-nemo-12b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64)
    p = [[1, 2, 3, 4, 5, 6, 7, 8]]
    a = engine.generate(p, max_new_tokens=8).tokens
    b = engine.generate(p, max_new_tokens=8).tokens
    np.testing.assert_array_equal(a, b)


def test_decode_compile_count_bounded_by_buckets():
    """The tentpole's load-bearing guarantee: generating T tokens compiles
    the decode step once per length *bucket* touched — never once per
    step.  32 tokens from a length-50 prompt cross one power-of-two
    boundary (64 -> 128), so exactly 2 decode traces are allowed."""
    import math

    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=256)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (50, 40)]
    steps = 32
    res = engine.generate(prompts, max_new_tokens=steps)
    assert res.tokens.shape == (2, steps)
    # buckets the run actually touched: needed cache = max_len_prompt + t + 1
    buckets = {engine._decode_bucket(50 + t + 1) for t in range(steps)}
    assert engine.decode_compiles == len(buckets) == 2
    assert engine.decode_compiles <= math.log2(engine.max_len)
    assert engine.decode_compiles < steps
    # a second generation touching the same buckets compiles nothing new
    engine.generate(prompts, max_new_tokens=4)
    assert engine.decode_compiles == 2


def test_heterogeneous_prompt_batch_matches_solo_runs():
    """Length-heterogeneous batches: right-padded prefill + per-request
    last-position gather + per-request cache-length masking must give each
    request exactly what a solo run gives it."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (8, 16)]
    batch = ServeEngine(cfg, params, max_batch=2, max_len=128)
    res = batch.generate(prompts, max_new_tokens=6)
    for i, p in enumerate(prompts):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=128)
        ref_toks = solo.generate([p], max_new_tokens=6).tokens[0]
        np.testing.assert_array_equal(res.tokens[i], ref_toks,
                                      err_msg=f"request {i}")


def test_heterogeneous_rejected_for_recurrent():
    cfg = registry.get_reduced("rwkv6-1.6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64)
    with pytest.raises(ValueError, match="recurrent"):
        engine.generate([[1, 2, 3], [1, 2, 3, 4]], max_new_tokens=2)


def test_continuous_batching_step_api():
    """submit()/step(): requests admitted and retired between decode steps
    produce the same tokens as batch generate, and the decode jit still
    compiles per bucket, not per step."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    p1 = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    p2 = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    p3 = list(map(int, rng.integers(0, cfg.vocab_size, 4)))

    engine = ServeEngine(cfg, params, max_batch=2, max_len=128)
    engine.submit(p1, max_new_tokens=5)
    engine.submit(p2, max_new_tokens=3)
    engine.submit(p3, max_new_tokens=4)   # queued until a slot frees up
    done = engine.run_until_drained()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    by_uid = {r.uid: r.tokens for r in done}
    assert [len(by_uid[u]) for u in (0, 1, 2)] == [5, 3, 4]
    assert engine.decode_compiles == 1    # everything fits one 64-bucket

    # per-request greedy tokens match solo generation
    for uid, prompt, n in ((0, p1, 5), (1, p2, 3), (2, p3, 4)):
        ref_toks = _solo_tokens(cfg, params, prompt, n)
        np.testing.assert_array_equal(np.asarray(by_uid[uid]), ref_toks,
                                      err_msg=f"request {uid}")


# --------------------------------------------------------------------------
# paged KV cache (PR 2 tentpole) + serve edge cases
# --------------------------------------------------------------------------

def test_page_allocator_unit():
    a = PageAllocator(num_pages=4, page_size=16)
    assert a.free_pages == 4
    assert a.pages_for(1) == 1 and a.pages_for(16) == 1
    assert a.pages_for(17) == 2 and a.pages_for(64) == 4
    got = a.alloc(3)
    assert len(got) == 3 and a.free_pages == 1
    assert a.alloc(2) is None, "partial allocation must be refused"
    assert a.free_pages == 1, "a refused alloc must not leak pages"
    a.free(got)
    assert a.free_pages == 4
    with pytest.raises(ValueError, match="free"):
        a.free([got[0]])            # double free
    with pytest.raises(ValueError, match="free"):
        a.free([99])                # out of range


@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-v2-lite-16b"])
def test_paged_engine_matches_dense_solo(arch):
    """The paged slot storage (pools + block tables + allocator) must be
    invisible to the tokens — GQA and MLA (latent pool + first_k_dense
    layers outside the scan) both gather back exactly the dense cache."""
    cfg = registry.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (7, 19)]
    engine = ServeEngine(cfg, params, max_batch=2, max_len=128,
                         page_size=16)
    assert engine.paged
    for p in prompts:
        engine.submit(p, max_new_tokens=6)
    done = engine.run_until_drained()
    by_uid = {r.uid: r.tokens for r in done}
    for uid, prompt in enumerate(prompts):
        ref_toks = _solo_tokens(cfg, params, prompt, 6)
        np.testing.assert_array_equal(np.asarray(by_uid[uid]), ref_toks,
                                      err_msg=f"request {uid}")
    # drained: every page is back in the pool (minus the reserved dump page)
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_slot_retirement_at_max_len_capacity():
    """A request hitting the cache capacity retires early (truncated, not
    wedged) and releases its slot AND pages for the next request."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    long_p = list(map(int, rng.integers(0, cfg.vocab_size, 60)))
    short_p = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64, page_size=16)
    engine.submit(long_p, max_new_tokens=50)   # only 4 fit: 60 -> 64
    engine.submit(short_p, max_new_tokens=3)   # queued behind it
    done = engine.run_until_drained()
    by_uid = {r.uid: r.tokens for r in done}
    assert len(by_uid[0]) == 4, "capacity must truncate, not hang"
    np.testing.assert_array_equal(
        np.asarray(by_uid[0]), _solo_tokens(cfg, params, long_p, 4, 128))
    np.testing.assert_array_equal(
        np.asarray(by_uid[1]), _solo_tokens(cfg, params, short_p, 3))
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_submit_after_drain_reuses_slots_and_pages():
    """A drained engine is not a dead engine: freed slots and pages serve
    the next wave, with no stale cache/table state leaking across."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    wave1 = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
             for n in (9, 13)]
    wave2 = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
             for n in (21, 5)]
    engine = ServeEngine(cfg, params, max_batch=2, max_len=128,
                         page_size=16)
    for p in wave1:
        engine.submit(p, max_new_tokens=4)
    engine.run_until_drained()
    free_between = engine.allocator.free_pages
    assert free_between == engine.num_pages - 1
    uids = [engine.submit(p, max_new_tokens=4) for p in wave2]
    done = engine.run_until_drained()
    by_uid = {r.uid: r.tokens for r in done}
    for uid, prompt in zip(uids, wave2):
        np.testing.assert_array_equal(
            np.asarray(by_uid[uid]), _solo_tokens(cfg, params, prompt, 4),
            err_msg=f"request {uid} after drain")
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_page_pool_exhaustion_queues_not_corrupts():
    """When the pool cannot hold another prompt, the request queues (FIFO)
    instead of being admitted — and the neighbour already decoding keeps
    producing exactly its solo tokens (no page is stolen or overwritten)."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(8)
    p1 = list(map(int, rng.integers(0, cfg.vocab_size, 20)))
    p2 = list(map(int, rng.integers(0, cfg.vocab_size, 20)))
    # 2 allocatable pages (3 minus dump) of 16 tokens: each prompt needs 2
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64,
                         page_size=16, num_pages=3)
    engine.submit(p1, max_new_tokens=4)
    engine.submit(p2, max_new_tokens=4)
    engine.step()
    assert len(engine.active_requests) == 1, "second request must queue"
    assert len(engine._queue) == 1
    done = engine.run_until_drained()
    by_uid = {r.uid: r.tokens for r in done}
    for uid, prompt in ((0, p1), (1, p2)):
        np.testing.assert_array_equal(
            np.asarray(by_uid[uid]), _solo_tokens(cfg, params, prompt, 4,
                                                  max_len=64),
            err_msg=f"request {uid}")
    # a prompt that can never fit is rejected up front, not deadlocked
    with pytest.raises(ValueError, match="pages"):
        engine.submit(list(range(40)), max_new_tokens=1)


def test_mid_decode_growth_preempts_youngest():
    """Allocate-on-write under pressure: when a growing cache needs a page
    and none is free, the youngest request is preempted and re-prefilled —
    both requests still produce exactly their solo tokens."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    pa = list(map(int, rng.integers(0, cfg.vocab_size, 16)))  # exactly 1 page
    pb = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    # 4 allocatable pages; each request grows 16 -> 36 tokens = 3 pages,
    # so both cannot finish resident at once
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64,
                         page_size=16, num_pages=5)
    engine.submit(pa, max_new_tokens=20)
    engine.submit(pb, max_new_tokens=20)
    done = engine.run_until_drained()
    by_uid = {r.uid: r.tokens for r in done}
    for uid, prompt in ((0, pa), (1, pb)):
        np.testing.assert_array_equal(
            np.asarray(by_uid[uid]),
            _solo_tokens(cfg, params, prompt, 20, max_len=64),
            err_msg=f"request {uid}")
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_prefill_compiles_bounded_by_chunk_shapes():
    """Satellite: the paged path prefills in page-aligned chunks whose
    capacities are page multiples, so N distinct prompt lengths cost at
    most #(chunk cap, kv bucket) pairs — not N traces."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(10)
    lens = [3, 5, 6, 7, 9, 11, 13, 15, 20, 31]   # all inside one 32-cap
    engine = ServeEngine(cfg, params, max_batch=2, max_len=128,
                         page_size=32)
    for n in lens:
        engine.submit(list(map(int, rng.integers(0, cfg.vocab_size, n))),
                      max_new_tokens=2)
    engine.run_until_drained()
    assert engine.prefill_compiles == 1, (
        f"{len(lens)} distinct prompt lengths must share one 32-token "
        f"chunk trace, saw {engine.prefill_compiles}")
    # a longer prompt needs the 64-cap tail chunk: exactly one more trace
    engine.submit(list(map(int, rng.integers(0, cfg.vocab_size, 40))),
                  max_new_tokens=2)
    engine.run_until_drained()
    assert engine.prefill_compiles == 2


def test_prefill_compiles_bounded_by_prompt_buckets_dense():
    """The dense submit/step path keeps the prompt-bucket padding bound:
    N distinct prompt lengths cost at most #buckets prefill traces."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(10)
    lens = [3, 5, 6, 7, 9, 11, 13, 15]          # all inside the 16-bucket
    engine = ServeEngine(cfg, params, max_batch=2, max_len=128,
                         prompt_bucket_lo=16, paged=False)
    for n in lens:
        engine.submit(list(map(int, rng.integers(0, cfg.vocab_size, n))),
                      max_new_tokens=2)
    engine.run_until_drained()
    assert engine.prefill_compiles == 1, (
        f"{len(lens)} distinct prompt lengths must share one 16-bucket "
        f"prefill trace, saw {engine.prefill_compiles}")
    # a longer prompt crosses into the 32-bucket: exactly one more trace
    engine.submit(list(map(int, rng.integers(0, cfg.vocab_size, 20))),
                  max_new_tokens=2)
    engine.run_until_drained()
    assert engine.prefill_compiles == 2


def test_growth_past_pool_capacity_truncates_not_livelocks():
    """A request whose context outgrows the entire pool cannot be
    re-admitted after self-preemption; it must retire truncated at pool
    capacity (like max_len truncation) instead of spinning forever and
    starving the queue behind it."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    big = list(map(int, rng.integers(0, cfg.vocab_size, 20)))   # 2 pages
    small = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    # 2 allocatable pages of 16: `big` can hold at most 32 context tokens
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64,
                         page_size=16, num_pages=3)
    engine.submit(big, max_new_tokens=20)
    engine.submit(small, max_new_tokens=3)
    done = engine.run_until_drained(max_steps=200)
    by_uid = {r.uid: r.tokens for r in done}
    assert len(by_uid[0]) == 13, (
        f"pool capacity (32 ctx) should truncate at 13 tokens, got "
        f"{len(by_uid[0])}")
    np.testing.assert_array_equal(
        np.asarray(by_uid[0]),
        _solo_tokens(cfg, params, big, 13, max_len=64))
    np.testing.assert_array_equal(
        np.asarray(by_uid[1]),
        _solo_tokens(cfg, params, small, 3, max_len=64))
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_preempted_at_max_len_retires_cleanly():
    """A request preempted with its context already at max_len must retire
    truncated on re-admission, not crash _grow_pages indexing past the
    block table (and the surviving request must be unaffected)."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(12)
    p_old = list(map(int, rng.integers(0, cfg.vocab_size, 15)))
    p_young = list(map(int, rng.integers(0, cfg.vocab_size, 30)))
    engine = ServeEngine(cfg, params, max_batch=2, max_len=32,
                         page_size=16, num_pages=4)
    engine.submit(p_old, max_new_tokens=30)
    engine.submit(p_young, max_new_tokens=30)
    done = engine.run_until_drained(max_steps=200)
    by_uid = {r.uid: r.tokens for r in done}
    assert sorted(by_uid) == [0, 1]
    for uid, prompt in ((0, p_old), (1, p_young)):
        n = len(by_uid[uid])
        assert 0 < n <= 32 - len(prompt)
        np.testing.assert_array_equal(
            np.asarray(by_uid[uid]),
            _solo_tokens(cfg, params, prompt, n, max_len=64),
            err_msg=f"request {uid}")
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_generate_only_engine_accepts_any_max_len():
    """The paged layout constraints (page_size | max_len) bind the
    submit/step pools, not the dense one-shot generate() path."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=1, max_len=100)  # not % 64
    res = engine.generate([[1, 2, 3, 4]], max_new_tokens=3)
    assert res.tokens.shape == (1, 3)
    with pytest.raises(ValueError, match="multiple"):
        engine.submit([1, 2, 3], max_new_tokens=2)  # paged path validates


def test_run_until_drained_raises_on_max_steps():
    """Satellite: exhausting max_steps with live requests must raise, not
    silently return partial results."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64)
    engine.submit([1, 2, 3], max_new_tokens=40)
    with pytest.raises(RuntimeError, match="still pending"):
        engine.run_until_drained(max_steps=3)
    # the request is intact and a follow-up drain completes it
    assert len(engine.active_requests) == 1
    done = engine.run_until_drained()
    assert len(done) == 1 and len(done[0].tokens) == 40


def test_multi_preemption_single_pass_preserves_admission_order():
    """Satellite regression: two preemptions inside one _grow_pages pass
    must requeue the victims in admission (seq) order — the old
    insert-at-front requeue depended on victim-selection order for this,
    and reversed it whenever an earlier victim was still queued.  Three
    one-page prompts on a 3-page pool all hit a page boundary on the
    same step: the oldest grows into the only reclaimable page, the
    middle one preempts the youngest and then itself — and the queue
    must read [middle, youngest], never [youngest, middle]."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 16)))
               for _ in range(3)]
    engine = ServeEngine(cfg, params, max_batch=3, max_len=64,
                         page_size=16, num_pages=4)
    uids = [engine.submit(p, max_new_tokens=8) for p in prompts]
    engine.step()
    assert engine.preemptions == 2, (
        "geometry drift: this test needs both victims evicted in the "
        f"same _grow_pages pass, saw {engine.preemptions} preemptions")
    queued = [r.uid for r in engine._queue]
    assert queued == [uids[1], uids[2]], (
        f"victims must requeue in admission order, got uids {queued}")
    assert [r.seq for r in engine._queue] == sorted(
        r.seq for r in engine._queue)
    done = engine.run_until_drained(max_steps=200)
    by_uid = {r.uid: r.tokens for r in done}
    for uid, prompt in zip(uids, prompts):
        np.testing.assert_array_equal(
            np.asarray(by_uid[uid]),
            _solo_tokens(cfg, params, prompt, 8, max_len=64),
            err_msg=f"request {uid}")
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_sole_request_all_pages_shared_self_preempts_cleanly():
    """Satellite regression: a sole active request whose pages are all
    prefix-cache hits frees no allocatable page by preempting others —
    it preempts *itself*, and victim selection on the now-empty active
    set must return None instead of raising (max() on an empty
    sequence).  The request then retires truncated at pool capacity on
    re-admission, exactly like the non-shared overflow path."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(14)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 32)))
    # 2 allocatable pages of 16: the 32-token prompt fills the pool
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64,
                         page_size=16, num_pages=3)
    engine.submit(list(prompt), max_new_tokens=1)
    first = engine.run_until_drained()
    assert len(first) == 1 and len(first[0].tokens) == 1
    # identical prompt: both pages come back as shared prefix hits, the
    # one recomputed token COWs / rides the partial page, and the first
    # decode write needs a third page that can never exist
    engine.submit(list(prompt), max_new_tokens=8)
    done = engine.run_until_drained(max_steps=50)   # must not ValueError
    assert len(done) == 1
    assert engine.preemptions >= 1, "the sole request must self-preempt"
    np.testing.assert_array_equal(
        np.asarray(done[0].tokens),
        _solo_tokens(cfg, params, prompt, len(done[0].tokens), max_len=64))
    assert len(done[0].tokens) >= 1
    assert engine.allocator.free_pages == engine.num_pages - 1
    engine.allocator.check_invariants()
