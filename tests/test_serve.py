"""Serving engine: generation consistency and bucketing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


@pytest.mark.parametrize("arch", ["deepseek-7b", "rwkv6-1.6b",
                                  "deepseek-v2-lite-16b"])
def test_greedy_generation_matches_manual_decode(arch):
    cfg = registry.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=128)
    rng = np.random.default_rng(0)
    plen = 16
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, plen)))
               for _ in range(2)]
    res = engine.generate(prompts, max_new_tokens=6)

    # manual reference: teacher-forced argmax continuation
    toks = jnp.asarray(prompts)
    caches = T.init_caches(cfg, 2, 128)
    logits, _, caches = T.apply(params, toks, cfg, caches=caches, cache_len=0)
    nxt = jnp.argmax(logits[:, -1], axis=-1)
    manual = [np.asarray(nxt)]
    clen = plen
    for _ in range(5):
        lg, _, caches = T.apply(params, nxt[:, None].astype(jnp.int32), cfg,
                                caches=caches, cache_len=clen)
        nxt = jnp.argmax(lg[:, -1], axis=-1)
        manual.append(np.asarray(nxt))
        clen += 1
    manual = np.stack(manual, axis=1)
    np.testing.assert_array_equal(res.tokens, manual)


def test_generation_is_deterministic_greedy():
    cfg = registry.get_reduced("mistral-nemo-12b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64)
    p = [[1, 2, 3, 4, 5, 6, 7, 8]]
    a = engine.generate(p, max_new_tokens=8).tokens
    b = engine.generate(p, max_new_tokens=8).tokens
    np.testing.assert_array_equal(a, b)


def test_decode_compile_count_bounded_by_buckets():
    """The tentpole's load-bearing guarantee: generating T tokens compiles
    the decode step once per length *bucket* touched — never once per
    step.  32 tokens from a length-50 prompt cross one power-of-two
    boundary (64 -> 128), so exactly 2 decode traces are allowed."""
    import math

    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=256)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (50, 40)]
    steps = 32
    res = engine.generate(prompts, max_new_tokens=steps)
    assert res.tokens.shape == (2, steps)
    # buckets the run actually touched: needed cache = max_len_prompt + t + 1
    buckets = {engine._decode_bucket(50 + t + 1) for t in range(steps)}
    assert engine.decode_compiles == len(buckets) == 2
    assert engine.decode_compiles <= math.log2(engine.max_len)
    assert engine.decode_compiles < steps
    # a second generation touching the same buckets compiles nothing new
    engine.generate(prompts, max_new_tokens=4)
    assert engine.decode_compiles == 2


def test_heterogeneous_prompt_batch_matches_solo_runs():
    """Length-heterogeneous batches: right-padded prefill + per-request
    last-position gather + per-request cache-length masking must give each
    request exactly what a solo run gives it."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (8, 16)]
    batch = ServeEngine(cfg, params, max_batch=2, max_len=128)
    res = batch.generate(prompts, max_new_tokens=6)
    for i, p in enumerate(prompts):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=128)
        ref_toks = solo.generate([p], max_new_tokens=6).tokens[0]
        np.testing.assert_array_equal(res.tokens[i], ref_toks,
                                      err_msg=f"request {i}")


def test_heterogeneous_rejected_for_recurrent():
    cfg = registry.get_reduced("rwkv6-1.6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64)
    with pytest.raises(ValueError, match="recurrent"):
        engine.generate([[1, 2, 3], [1, 2, 3, 4]], max_new_tokens=2)


def test_continuous_batching_step_api():
    """submit()/step(): requests admitted and retired between decode steps
    produce the same tokens as batch generate, and the decode jit still
    compiles per bucket, not per step."""
    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    p1 = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    p2 = list(map(int, rng.integers(0, cfg.vocab_size, 16)))
    p3 = list(map(int, rng.integers(0, cfg.vocab_size, 4)))

    engine = ServeEngine(cfg, params, max_batch=2, max_len=128)
    engine.submit(p1, max_new_tokens=5)
    engine.submit(p2, max_new_tokens=3)
    engine.submit(p3, max_new_tokens=4)   # queued until a slot frees up
    done = engine.run_until_drained()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    by_uid = {r.uid: r.tokens for r in done}
    assert [len(by_uid[u]) for u in (0, 1, 2)] == [5, 3, 4]
    assert engine.decode_compiles == 1    # everything fits one 64-bucket

    # per-request greedy tokens match solo generation
    for uid, prompt, n in ((0, p1, 5), (1, p2, 3), (2, p3, 4)):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=128)
        ref_toks = solo.generate([prompt], max_new_tokens=n).tokens[0]
        np.testing.assert_array_equal(np.asarray(by_uid[uid]), ref_toks,
                                      err_msg=f"request {uid}")
