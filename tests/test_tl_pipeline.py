"""The paper's workflow: sketch -> reason -> validate (+ Appendix-B
ablation) and the autotuner's VMEM invariant."""

import pytest
from hypothesis_compat import given, settings, st

from repro.core import autotune
from repro.core.llm import DeterministicBackend, OneStageBackend
from repro.core.reason import BlockConfig, reason_parameters, _vmem_bytes
from repro.core.sketch import generate_sketch
from repro.core.spec import AttnSpec
from repro.core.target import get_target
from repro.core.tl.parser import parse
from repro.core.tl.validator import TLValidationError, check, validate

SPECS = [
    AttnSpec.mha(16, 128),
    AttnSpec.gqa(32, 8, 128),
    AttnSpec.mqa(32, 64),
    AttnSpec.mla(16),
    AttnSpec.gqa(32, 8, 128, causal=False),
    AttnSpec.mha(16, 64, window=512),
]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: f"{s.variant}-{s.head_dim}-{s.causal}")
def test_sketch_reason_validate(spec):
    sk = generate_sketch(spec)
    # sketches themselves are clean (non-strict mode)
    assert not [d for d in validate(sk, strict_alloc=False) if d.is_error]
    prog = reason_parameters(sk, spec, q_len=1024, kv_len=2048)
    check(prog)  # no errors
    # the critical fusion statement is present
    from repro.core.tl.ast import Reshape
    assert prog.find(Reshape), "reasoning must insert the Reshape"


def test_reshape_omission_caught():
    """Paper Appendix B, Listing 1."""
    spec = AttnSpec.mha(16, 128)
    prog = reason_parameters(generate_sketch(spec), spec, q_len=512,
                             kv_len=512, omit_reshape=True)
    with pytest.raises(TLValidationError) as ei:
        check(prog)
    assert any(d.code == "E001" for d in ei.value.diagnostics)


def test_gemm_layout_error_caught():
    """Paper Appendix B, Listing 2."""
    spec = AttnSpec.mha(16, 128)
    prog = reason_parameters(generate_sketch(spec), spec, q_len=512,
                             kv_len=512, gemm_layout_bug=True)
    with pytest.raises(TLValidationError) as ei:
        check(prog)
    assert any(d.code == "E002" for d in ei.value.diagnostics)


def test_one_stage_backend_reproduces_failures():
    for failure, code in [("reshape_omission", "E001"),
                          ("gemm_layout_error", "E002")]:
        backend = OneStageBackend(failure)
        txt = backend.generate_tl_code(AttnSpec.mha(8, 64), 256, 256,
                                       get_target("v5e"))
        prog = parse(txt)
        prog.meta["stage"] = "code"
        prog.outputs = ("O",)
        # re-derive params the pipeline way
        from repro.core.reason import reason_parameters as rp
        from repro.core.sketch import generate_sketch as gs
        spec = AttnSpec.mha(8, 64)
        prog.params = rp(gs(spec), spec, q_len=256, kv_len=256).params
        assert any(d.code == code for d in validate(prog))


def test_vmem_overflow_caught():
    spec = AttnSpec.mha(16, 128)
    prog = reason_parameters(generate_sketch(spec), spec, q_len=8192,
                             kv_len=8192, blocks=BlockConfig(2048, 4096))
    diags = validate(prog)
    assert any(d.code == "E004" for d in diags)


def test_backend_text_roundtrip():
    backend = DeterministicBackend()
    spec = AttnSpec.gqa(16, 4, 128)
    sk_text = backend.generate_sketch(spec)
    assert "Online_softmax" in sk_text and "Reshape" not in sk_text
    code_text = backend.reason_parameters(sk_text, spec, 1024, 1024,
                                          get_target("v5e"), None)
    assert "Reshape" in code_text and "Allocate" in code_text


@given(
    q_heads=st.sampled_from([8, 16, 32, 64, 128]),
    kv_div=st.sampled_from([1, 2, 4, 8]),
    head_dim=st.sampled_from([64, 128]),
    q_len=st.integers(16, 40000),
    kv_len=st.integers(128, 40000),
    causal=st.booleans(),
)
@settings(max_examples=60, deadline=None)
def test_autotuner_always_fits_vmem(q_heads, kv_div, head_dim, q_len,
                                    kv_len, causal):
    """Property: tuned blocks always respect the VMEM budget and tile the
    MXU-aligned sizes."""
    spec = AttnSpec.gqa(q_heads, max(1, q_heads // kv_div), head_dim,
                        causal=causal)
    t = get_target("v5e")
    res = autotune.tune(spec, q_len, kv_len, t)
    assert _vmem_bytes(spec, res.blocks.bm, res.blocks.bn) <= t.vmem_budget
    assert res.blocks.bm % 8 == 0 and res.blocks.bn % 128 == 0
    assert res.est_time_s > 0


def test_autotuner_mla_prefers_smaller_bm():
    """MLA's 576-wide qk tile must squeeze BM to fit VMEM."""
    mla = autotune.tune(AttnSpec.mla(128), 4096, 4096, "v5e")
    mha = autotune.tune(AttnSpec.mha(128, 128), 4096, 4096, "v5e")
    assert mla.blocks.bm <= mha.blocks.bm
