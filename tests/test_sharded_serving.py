"""Tensor-parallel sharded serving: head-sharded paged attention on a mesh.

The contract under test (this PR's tentpole): ``ServeEngine(mesh=...)``
runs its whole hot path — decode, chunked prefill, speculative verify,
split-KV combine — inside ``shard_map`` over a ``('data', 'model')`` mesh,
sharding attention heads (GQA 'kv'/'q' plans) or the KV sequence (MLA
'seq' plan) over the model axis, and the *committed token streams are
bit-identical* to the single-device engine.  Host-side scheduler state
(allocator, block tables, scale tables, prefix index) stays replicated, so
every serving feature — prefix cache, COW, preemption, kv_quant,
spec-decode — composes with the mesh unchanged.

Anything needing >1 device runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (in-process tests keep one
device, per the dry-run isolation rule).  Plan selection, the q-head
permutation, and the PartitionSpec rules are pure and test in-process.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax

from repro.launch.mesh import make_host_mesh
from repro.models import registry, transformer
from repro.parallel import (
    choose_serve_plan,
    param_pspec,
    q_head_permutation,
    serve_cache_pspec,
    serve_param_pspec,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# --------------------------------------------------------------------------
# token-stream identity: sharded engine == single-device engine
# --------------------------------------------------------------------------

_IDENTITY_PRELUDE = """
    import json
    import jax
    from repro.models import registry, transformer
    from repro.serve.engine import ServeEngine
    from repro.launch.mesh import make_host_mesh

    PROMPTS = [[1, 2, 3, 4, 5, 6, 7], [3, 1, 4, 1, 5, 9, 2, 6, 5, 3],
               [7, 7, 7], [2, 7, 1, 8, 2, 8]]

    def serve(mesh, cfg, params, steps=6, **kw):
        eng = ServeEngine(cfg, params, max_batch=4, max_len=256,
                          page_size=16, decode_bucket_lo=16, mesh=mesh,
                          **kw)
        for p in PROMPTS:
            eng.submit(p, max_new_tokens=steps)
        done = eng.run_until_drained()
        return {r.uid: list(r.tokens) for r in done}, eng

    res = {}
    for name, arch, over, mp, expect_plan, kw in CASES:
        cfg = registry.get_reduced(arch, **over)
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        ref, _ = serve(None, cfg, params, **kw)
        out, eng = serve(make_host_mesh(model_axis=mp), cfg, params, **kw)
        entry = {"plan": eng._tp.plan,
                 "plan_ok": eng._tp.plan == expect_plan,
                 "size_ok": eng._tp.size == mp,
                 "match": ref == out,
                 "decode_keys_ok":
                     eng.decode_compiles == len(eng._decode_keys),
                 "verify_keys_ok":
                     eng.verify_compiles == len(eng._verify_keys)}
        if not entry["match"]:
            entry["ref"], entry["out"] = ref, out
        res[name] = entry
    print(json.dumps(res))
"""


def _identity(cases) -> dict:
    out = _run(f"CASES = {cases!r}\n" + textwrap.dedent(_IDENTITY_PRELUDE))
    return json.loads(out.strip().splitlines()[-1])


def _assert_all(res: dict):
    for name, e in res.items():
        assert e["plan_ok"] and e["size_ok"], (name, e)
        assert e["decode_keys_ok"] and e["verify_keys_ok"], \
            (name, "silent retrace under mesh")
        assert e["match"], (name, e)


def test_sharded_token_identity_head_plans():
    """GQA/MQA head plans at model_axis 2 and 4: committed tokens are
    bit-identical to the single-device engine, and the compile-count
    contract (no silent retraces) holds under the mesh."""
    _assert_all(_identity([
        ("gqa-kv-mp2", "deepseek-7b", {}, 2, "kv", {}),
        ("gqa-kv-mp4", "deepseek-7b", {}, 4, "kv", {}),
        ("mqa-q-mp2", "deepseek-7b", {"num_kv_heads": 1}, 2, "q", {}),
    ]))


def test_sharded_token_identity_q_plan_group_permutation():
    """Hkv=2 over a 4-wide axis: KV heads can't shard, so the 'q' plan
    splits each KV head's query group — valid only through the
    group-interleaved head permutation (a contiguous slice would pair
    shard 1's queries with the wrong KV head)."""
    _assert_all(_identity([
        ("gqa-qperm-mp4", "mistral-nemo-12b",
         {"num_q_heads": 8, "num_kv_heads": 2}, 4, "q", {}),
    ]))


def test_sharded_token_identity_mla_seq_plan():
    """MLA has one latent KV head, so the 'seq' plan shards the page-table
    columns instead: each rank attends over its sequence slice and the
    per-rank online-softmax states LSE-merge — split-KV decode with the
    mesh axis as the split grid, bit-identical by the same algebra.
    Covers both attention backends (xla flash + TL-generated Pallas)."""
    _assert_all(_identity([
        ("mla-seq-mp2", "deepseek-v2-lite-16b", {"moe": False}, 2, "seq",
         {}),
        ("mla-seq-mp4-tl", "deepseek-v2-lite-16b",
         {"moe": False, "attn_impl": "tl_pallas"}, 4, "seq", {}),
    ]))


def test_sharded_token_identity_spec_decode_and_kv_quant():
    """Serving features compose with the mesh: int8-quantized KV pages
    (per-page scales stay replicated — the kv plan cross-shard-maxes the
    amax so every rank quantizes with the same scale) and speculative
    decoding (sharded verify dispatch + replicated rollback) both keep
    the committed stream bit-identical.  One TL-Pallas arm covers the
    generated kernels' shard path under the kv plan."""
    _assert_all(_identity([
        ("gqa-kv-quant-spec-mp2", "deepseek-7b", {}, 2, "kv",
         {"kv_quant": True, "spec_decode": True}),
        ("mla-seq-quant-spec-mp2", "deepseek-v2-lite-16b", {"moe": False},
         2, "seq", {"kv_quant": True, "spec_decode": True}),
        ("gqa-kv-mp2-tl", "deepseek-7b", {"attn_impl": "tl_pallas"}, 2,
         "kv", {}),
    ]))


def test_sharded_engine_contracts_and_replicated_scheduler():
    """Mesh-engine API contract: dense paths refuse (generate(), paged
    off), a mesh without a 'model' axis refuses, the MLA seq plan
    validates max_len divisibility up front — and the host-side
    scheduler counters (prefix cache, COW) are *equal* between the
    sharded and single-device arms, the replicated-scheduler invariant."""
    out = _run("""
        import json
        import jax
        from repro.models import registry, transformer
        from repro.serve.engine import ServeEngine
        from repro.launch.mesh import make_host_mesh

        cfg = registry.get_reduced("deepseek-7b")
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        mesh = make_host_mesh(model_axis=2)
        res = {}

        eng = ServeEngine(cfg, params, max_batch=2, max_len=128,
                          page_size=16, mesh=mesh)
        try:
            eng.generate([[1, 2, 3]])
            res["generate_raises"] = False
        except ValueError:
            res["generate_raises"] = True
        try:
            ServeEngine(cfg, params, paged=False, mesh=mesh)
            res["dense_raises"] = False
        except ValueError:
            res["dense_raises"] = True
        try:
            ServeEngine(cfg, params, mesh=jax.make_mesh((8,), ("x",)))
            res["no_model_axis_raises"] = False
        except ValueError:
            res["no_model_axis_raises"] = True
        mla = registry.get_reduced("deepseek-v2-lite-16b", moe=False)
        mla_params = transformer.init_params(jax.random.PRNGKey(0), mla)
        try:
            # 48 is a page multiple but not a page_size*model_axis multiple
            ServeEngine(mla, mla_params, max_len=48, page_size=16,
                        mesh=make_host_mesh(model_axis=4))
            res["seq_max_len_raises"] = False
        except ValueError:
            res["seq_max_len_raises"] = True

        # replicated scheduler: identical shared-prefix workload on both
        # arms -> identical prefix/COW counters and token streams
        shared = list(range(1, 33))
        def serve(mesh):
            e = ServeEngine(cfg, params, max_batch=4, max_len=256,
                            page_size=16, decode_bucket_lo=16, mesh=mesh)
            for tail in ([40], [40], [41, 42]):
                e.submit(shared + tail, max_new_tokens=4)
            done = e.run_until_drained()
            toks = {r.uid: list(r.tokens) for r in done}
            s = e.stats()
            ctr = {k: s[k] for k in ("prefix_hits", "prefix_hit_tokens",
                                     "prefill_tokens", "cow_count",
                                     "preemptions")}
            return toks, ctr
        t_ref, c_ref = serve(None)
        t_out, c_out = serve(make_host_mesh(model_axis=2))
        res["prefix_reused"] = c_out["prefix_hits"] > 0
        res["counters_match"] = c_ref == c_out
        res["tokens_match"] = t_ref == t_out
        print(json.dumps(res))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert all(res.values()), res


# --------------------------------------------------------------------------
# TL-backend level: shard-aware translation under shard_map
# --------------------------------------------------------------------------

def test_tl_backends_shard_axis_matches_unsharded():
    """The TL translation layer's shard contract, below the engine: a
    decode program translated with ``shard_axis`` and run inside
    shard_map — each rank scanning its KV slice with a rank-local length
    — matches the unsharded program over the full cache.  Covers the jnp
    oracle (lse_merge_axis before the epilogue) and the Pallas backend
    (per-rank partial states all-gathered into the combine), paged MLA
    included; a rank whose local length goes negative masks everything
    and merges with zero weight."""
    out = _run("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.pipeline import cached_kernel
        from repro.core.spec import AttnSpec
        from repro.core.translate import translate_jnp
        from repro.kernels import ops
        from repro.launch.mesh import make_host_mesh

        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:
            from jax.experimental.shard_map import shard_map
        mesh = make_host_mesh(model_axis=2)
        rng = np.random.default_rng(0)
        res = {}

        # --- jnp oracle: dense runtime-length decode, KV row-sharded ----
        bucket, d, g = 128, 32, 4
        loc = bucket // 2
        spec = AttnSpec(variant="mha", num_q_heads=1, num_kv_heads=1,
                        head_dim=d, causal=False, mode="decode",
                        dtype="f32")
        full = cached_kernel(spec, g, bucket, "v5e", True, False)
        part = cached_kernel(spec, g, loc, "v5e", True, False)
        oracle_sh = translate_jnp(part.program, shard_axis="model")
        q = jnp.asarray(rng.standard_normal((g, d)) * 0.5, jnp.float32)
        k = jnp.asarray(rng.standard_normal((bucket, d)) * 0.5,
                        jnp.float32)
        v = jnp.asarray(rng.standard_normal((bucket, d)) * 0.5,
                        jnp.float32)
        for cache_len in (1, loc - 3, loc, bucket - 5, bucket):
            gold = full.oracle_fn(cache_len, q, k, v)

            def local(q, k, v):
                rank = jax.lax.axis_index("model")
                return oracle_sh(cache_len - rank * loc, q, k, v)

            try:
                f = shard_map(local, mesh=mesh,
                              in_specs=(P(), P("model", None),
                                        P("model", None)),
                              out_specs=P(), check_vma=False)
            except TypeError:
                f = shard_map(local, mesh=mesh,
                              in_specs=(P(), P("model", None),
                                        P("model", None)),
                              out_specs=P(), check_rep=False)
            got = f(q, k, v)
            ok = np.allclose(np.asarray(got), np.asarray(gold),
                             atol=1e-5, rtol=1e-5)
            res[f"oracle_len{cache_len}"] = bool(ok)

        # --- Pallas backend: paged MLA decode, table columns sharded ----
        b, h, r, rr, ps = 2, 4, 32, 16, 16
        pool_pages, tpc = 24, bucket // ps
        ql = jnp.asarray(rng.standard_normal((b, h, 1, r + rr)) * 0.5,
                         jnp.float32)
        pool = jnp.asarray(
            rng.standard_normal((pool_pages, ps, r + rr)) * 0.5,
            jnp.float32)
        tables = jnp.asarray(
            rng.permutation(pool_pages)[: b * tpc].reshape(b, tpc))
        lens = jnp.asarray([bucket - 7, loc - 3])
        gold = ops.paged_mla_decode(ql, pool, tables, cache_len=lens,
                                    kv_lora_rank=r, rope_head_dim=rr)

        def mla_local(ql, pool, tables, lens):
            rank = jax.lax.axis_index("model")
            tpr = tables.shape[1] // 2
            tbl = jax.lax.dynamic_slice_in_dim(tables, rank * tpr, tpr,
                                               axis=1)
            return ops.paged_mla_decode(
                ql, pool, tbl, cache_len=lens - rank * (tpr * ps),
                kv_lora_rank=r, rope_head_dim=rr, shard_axis="model")

        specs = dict(mesh=mesh, in_specs=(P(), P(), P(), P()),
                     out_specs=P())
        try:
            f = shard_map(mla_local, check_vma=False, **specs)
        except TypeError:
            f = shard_map(mla_local, check_rep=False, **specs)
        got = f(ql, pool, tables, lens)
        res["pallas_paged_mla"] = bool(np.allclose(
            np.asarray(got), np.asarray(gold), atol=1e-5, rtol=1e-5))
        print(json.dumps(res))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert all(res.values()), res


# --------------------------------------------------------------------------
# satellite: make_host_mesh divisor fallback
# --------------------------------------------------------------------------

def test_make_host_mesh_divisor_fallback():
    """A model_axis request that doesn't divide the device count falls
    back to the largest divisor <= the request, so (data, model) always
    covers all devices — no crash, no dropped devices."""
    out = _run("""
        import json, jax
        from repro.launch.mesh import make_host_mesh
        shapes = {}
        for req in (1, 2, 3, 5, 8):
            m = make_host_mesh(model_axis=req)
            shapes[str(req)] = [int(m.shape["data"]), int(m.shape["model"])]
        print(json.dumps(shapes))
    """)
    shapes = json.loads(out.strip().splitlines()[-1])
    assert shapes == {"1": [8, 1], "2": [4, 2], "3": [4, 2],
                      "5": [2, 4], "8": [1, 8]}, shapes


def test_make_host_mesh_single_device_in_process():
    mesh = make_host_mesh(model_axis=3)
    assert dict(mesh.shape) == {"data": len(jax.devices()), "model": 1}


# --------------------------------------------------------------------------
# satellite: plan ladder / permutation / pspec rules (pure, in-process)
# --------------------------------------------------------------------------

def test_choose_serve_plan_ladder():
    gqa = registry.get_reduced("deepseek-7b")             # 4q / 4kv
    mqa = registry.get_reduced("deepseek-7b", num_kv_heads=1)
    nemo = registry.get_reduced("mistral-nemo-12b",
                                num_q_heads=8, num_kv_heads=2)
    mla = registry.get_reduced("deepseek-v2-lite-16b", moe=False)

    tp = choose_serve_plan(gqa, 1)
    assert (tp.plan, tp.size, tp.ffn) == ("replicate", 1, False)
    assert choose_serve_plan(gqa, 2).plan == "kv"
    assert choose_serve_plan(gqa, 4).plan == "kv"
    assert choose_serve_plan(gqa, 2).ffn          # d_ff=128 divides
    # Hkv doesn't divide -> fall through to the q plan when the group does
    assert choose_serve_plan(mqa, 2).plan == "q"
    assert choose_serve_plan(nemo, 4).plan == "q"
    # neither heads nor groups divide -> replicate (still valid)
    assert choose_serve_plan(mqa, 3).plan == "replicate"
    # MLA: seq on power-of-two axes only (bucket divisibility)
    assert choose_serve_plan(mla, 2).plan == "seq"
    assert choose_serve_plan(mla, 4).plan == "seq"
    assert choose_serve_plan(mla, 3).plan == "replicate"
    # padded q heads (56 -> 64 coder): the pad is a kernel-layout fiction,
    # sharding it would split a partial head -> replicate
    coder = registry.get_config("deepseek-coder-33b")
    assert coder.pad_q_heads_to > coder.num_q_heads
    assert choose_serve_plan(coder, 2).plan == "replicate"
    # recurrent mixers keep their own layouts -> replicate, no FFN split
    rwkv = registry.get_reduced("rwkv6-1.6b")
    tp = choose_serve_plan(rwkv, 2)
    assert (tp.plan, tp.ffn) == ("replicate", False)


def test_q_head_permutation_grouped_reshape_invariant():
    """The permutation's defining property: shard ``s``'s local head
    ``kv * gl + j`` is global head ``perm[s * hl + kv * gl + j]`` and must
    belong to KV head ``kv`` — then the local grouped reshape
    (hq_loc -> (hkv, gl)) pairs every query with its true KV head."""
    nemo = registry.get_reduced("mistral-nemo-12b",
                                num_q_heads=8, num_kv_heads=2)
    for cfg, mp in ((nemo, 2), (nemo, 4),
                    (registry.get_reduced("deepseek-7b",
                                          num_kv_heads=1), 2)):
        hq, hkv = cfg.num_q_heads, cfg.num_kv_heads
        g, gl = hq // hkv, hq // hkv // mp
        hl = hq // mp
        perm = q_head_permutation(cfg, mp)
        assert sorted(perm) == list(range(hq))
        for s in range(mp):
            for kv in range(hkv):
                for j in range(gl):
                    assert perm[s * hl + kv * gl + j] // g == kv
    # MQA: one KV head, any contiguous slice works -> identity
    mqa = registry.get_reduced("deepseek-7b", num_kv_heads=1)
    assert q_head_permutation(mqa, 2) == list(range(4))


def _collect_specs(tree, fn):
    """name -> set of PartitionSpecs across the tree (stacked layers give
    the same base rule, so each name maps to one spec)."""
    out = {}
    jax.tree_util.tree_map_with_path(
        lambda p, l: out.setdefault(
            _name(p), set()).add(tuple(fn(p, l))), tree)
    return out


def _name(path):
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return str(e.key)
    return ""


def test_serve_pspec_rules():
    cfg = registry.get_reduced("deepseek-7b")
    abs_p = transformer.abstract_params(cfg)
    kv = choose_serve_plan(cfg, 2)
    specs = _collect_specs(abs_p, lambda p, l: serve_param_pspec(p, l, kv))
    # kv plan: q/k/v column-parallel on the head dim, wo row-parallel
    # (leading axis = the scan-stacked layer dim, always replicated)
    assert specs["wq"] == {(None, None, "model", None)}
    assert specs["wk"] == {(None, None, "model", None)}
    assert specs["wo"] == {(None, "model", None, None)}
    assert specs["w_gate"] == {(None, None, "model")}
    assert specs["w_down"] == {(None, "model", None)}
    assert specs["table"] == {()} and specs["lm_head"] == {()}

    q = choose_serve_plan(registry.get_reduced("deepseek-7b",
                                               num_kv_heads=1), 2)
    qs = _collect_specs(abs_p, lambda p, l: serve_param_pspec(p, l, q))
    # q plan: KV projections stay replicated, only wq/wo shard
    assert qs["wq"] == {(None, None, "model", None)}
    assert qs["wk"] == {()} and qs["wv"] == {()}
    assert qs["wo"] == {(None, "model", None, None)}

    caches = transformer.init_caches(cfg, 2, 64, paged=True, page_size=16,
                                     num_pages=9, kv_quant=True)
    cs = _collect_specs(caches, lambda p, l: serve_cache_pspec(p, l, kv))
    # kv plan: pools shard the head axis of (layers, P, Hkv, page, d);
    # per-page scale tables replicate — they must stay host-identical
    assert cs["k"] == {(None, None, "model", None, None)}
    assert cs["v"] == {(None, None, "model", None, None)}
    assert cs["ks"] == {()} and cs["vs"] == {()}
    # seq plan (MLA): everything replicated on-device
    mla = registry.get_reduced("deepseek-v2-lite-16b", moe=False)
    seq = choose_serve_plan(mla, 2)
    mcaches = transformer.init_caches(mla, 2, 64, paged=True, page_size=16,
                                      num_pages=9)
    ms = _collect_specs(mcaches,
                        lambda p, l: serve_cache_pspec(p, l, seq))
    assert all(v == {()} for v in ms.values()), ms


class _FakeMesh:
    """shape/axis_names stand-in: param_pspec only reads those, and a real
    16x16 Mesh needs 256 devices."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_param_pspec_fallback_ladder_full_configs():
    """The training-side rules on awkward *full* configs over a 16-wide
    model axis: every sharded dim must divide its axis (the fallback
    ladder's whole job), 56-head coder and kv=4 Qwen included."""
    mesh = _FakeMesh(data=16, model=16)

    def axis_size(ax):
        return 16

    for arch in ("deepseek-coder-33b", "qwen3-moe-235b-a22b"):
        cfg = registry.get_config(arch)
        abs_p = transformer.abstract_params(cfg)

        def check(path, leaf):
            spec = param_pspec(path, leaf, mesh)
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                assert leaf.shape[dim] % axis_size(ax) == 0, \
                    (arch, _name(path), leaf.shape, tuple(spec))
            return spec

        specs = _collect_specs(abs_p, check)
        if arch == "deepseek-coder-33b":
            # 56 q heads pad to 64 in the kernel layout (pad_q_heads_to),
            # and the *padded* dim divides 16 — so the parameter sharding
            # keeps TP on the head dim while *serving* must replicate
            # (choose_serve_plan's padded rung, tested above)
            assert specs["wq"] == {(None, "data", "model", None)}, \
                specs["wq"]
        else:
            # kv=4 Qwen: wk/wv head dim can't take the 16-wide axis
            assert all("model" not in s for s in specs["wk"]), specs["wk"]
            # but experts (E=128) shard expert-parallel on it
            assert any("model" in s for s in specs["we_gate"])
