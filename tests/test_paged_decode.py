"""Paged KV-cache decode kernels: block-table gather parity with the dense
runtime-length kernels, the jnp oracle, and the closed-form reference.

The contract under test (this PR's tentpole): a paged decode program reads
its KV cache as a pool of ``page_size``-token pages addressed through a
per-request block table — a second runtime operand next to the cache
length.  Whatever the physical page placement (contiguous, permuted,
interleaved with other requests' pages), the result must be bitwise-close
to decoding the same logical cache densely, for every head geometry and
dtype, and the compiled-kernel count must stay bounded by the buckets
touched.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core.pipeline import cached_kernel
from repro.core.reason import ReasonError, reason_parameters
from repro.core.sketch import generate_sketch
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 1e-2}

_DT = {"bfloat16": "bf16", "float32": "f32"}


def _paged_case(rng, *, b, hq, hkv, d, ps, tp, pool_pages, dtype):
    """Random pool + per-row permuted, non-contiguous block tables, plus
    the dense per-row view the table encodes."""
    kp = jnp.asarray(rng.standard_normal((pool_pages, hkv, ps, d)) * 0.5,
                     dtype)
    vp = jnp.asarray(rng.standard_normal((pool_pages, hkv, ps, d)) * 0.5,
                     dtype)
    # every row draws tp distinct pages from the pool, in arbitrary order;
    # rows may not overlap (each page belongs to one request)
    perm = rng.permutation(pool_pages)[: b * tp]
    tables = np.asarray(perm, np.int32).reshape(b, tp)
    kd = jnp.stack([jnp.concatenate([kp[t] for t in row], axis=1)
                    for row in tables])
    vd = jnp.stack([jnp.concatenate([vp[t] for t in row], axis=1)
                    for row in tables])
    return kp, vp, tables, kd, vd


@pytest.mark.parametrize("seed", range(10))
def test_paged_flash_decode_matches_dense_and_ref(seed):
    """Paged decode == dense runtime-length decode == closed-form reference
    for random (page_size, bucket, geometry, dtype, cache_len) draws."""
    rng = np.random.default_rng(seed)
    hq, hkv = [(4, 4), (8, 2), (4, 1), (6, 3)][seed % 4]   # MHA/GQA/MQA
    d = int(rng.choice([32, 64]))
    ps = int(rng.choice([16, 32, 64]))
    tp = int(rng.choice([1, 2, 4]))
    dtype = [jnp.float32, jnp.float32, jnp.bfloat16][seed % 3]
    b = 2
    bucket = ps * tp
    cache_len = int(rng.integers(1, bucket + 1))
    kp, vp, tables, kd, vd = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, tp=tp,
        pool_pages=b * tp + 3, dtype=dtype)
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, dtype)

    out = ops.paged_flash_decode(q, kp, vp, tables, cache_len=cache_len)
    dense = ops.flash_decode(q, kd, vd, cache_len=cache_len)
    # paged clamps BN to the page size, so the online softmax may visit the
    # cache in different block partitions than dense — identical logical
    # values, f32-tight, one-ulp-loose at bf16 output precision
    tol = 1e-6 if dtype == jnp.float32 else TOL[dtype]
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(dense, np.float32),
        atol=tol, rtol=tol,
        err_msg=f"paged != dense: ps={ps} tp={tp} Hq={hq} Hkv={hkv}")
    gold = ref.decode_attention(q, kd, vd, cache_len=cache_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(gold, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
        err_msg=f"paged != ref: ps={ps} tp={tp} len={cache_len}")


def test_paged_decode_per_row_lengths_and_tables():
    """Heterogeneous batches: each row has its own cache length AND its own
    scattered pages; table entries past a row's used pages point anywhere
    valid (the engine's dump page) and must not leak into the output."""
    rng = np.random.default_rng(42)
    b, hq, hkv, d, ps, tp = 3, 8, 2, 32, 32, 4
    kp, vp, tables, kd, vd = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, tp=tp,
        pool_pages=b * tp + 1, dtype=jnp.float32)
    # rows use 1, 57 and 128 entries; redirect the unused tail of row 0's
    # table at row 2's pages — a live neighbour — to prove masking wins
    tables = tables.copy()
    tables[0, 1:] = tables[2, 1:]
    lens = np.asarray([1, 57, 128], np.int32)
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, jnp.float32)
    out = ops.paged_flash_decode(q, kp, vp, tables,
                                 cache_len=jnp.asarray(lens))
    kd = jnp.stack([jnp.concatenate([kp[t] for t in row], axis=1)
                    for row in tables])
    vd = jnp.stack([jnp.concatenate([vp[t] for t in row], axis=1)
                    for row in tables])
    for i, cl in enumerate(lens):
        gold = ref.decode_attention(q[i:i + 1], kd[i:i + 1], vd[i:i + 1],
                                    cache_len=int(cl))
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(gold, np.float32),
                                   atol=1e-5, rtol=1e-5, err_msg=f"row {i}")


@pytest.mark.parametrize("seed", range(4))
def test_paged_pallas_vs_jnp_oracle(seed):
    """Backend agreement on the same paged TL program: the Pallas kernel's
    block-table gather and the jnp oracle's must be the same function."""
    rng = np.random.default_rng(100 + seed)
    hq, hkv, d, ps, tp = 8, 2, 32, 32, 2
    bucket = ps * tp
    dtype = jnp.float32 if seed % 2 else jnp.bfloat16
    b = 2
    kp, vp, tables, _, _ = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, tp=tp,
        pool_pages=b * tp + 2, dtype=dtype)
    lens = np.asarray([int(rng.integers(1, bucket + 1)) for _ in range(b)],
                      np.int32)
    g = hq // hkv
    spec = AttnSpec(variant="mha", num_q_heads=hkv, num_kv_heads=hkv,
                    head_dim=d, causal=False, mode="decode",
                    dtype=_DT[jnp.dtype(dtype).name], page_size=ps)
    kern = cached_kernel(spec, g, bucket, "v5e", True, False)
    assert kern.pallas_fn.paged and kern.oracle_fn.paged
    assert kern.pallas_fn.page_size == kern.oracle_fn.page_size == ps
    q = jnp.asarray(rng.standard_normal((b, hkv, g, d)) * 0.5, dtype)
    qp = ops._pad_rows(q, 2, kern.blocks.bm)
    out = kern.pallas_fn(jnp.asarray(lens), jnp.asarray(tables), qp, kp, vp)
    for bi in range(b):
        for h in range(hkv):
            o = kern.oracle_fn(int(lens[bi]), tables[bi], qp[bi, h],
                               kp[:, h].reshape(-1, d),
                               vp[:, h].reshape(-1, d))[:g]
            np.testing.assert_allclose(
                np.asarray(out[bi, h, :g], np.float32),
                np.asarray(o, np.float32),
                atol=TOL[dtype], rtol=TOL[dtype],
                err_msg=f"row {bi} kv-head {h}")


# --------------------------------------------------------------------------
# MLA
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_paged_mla_decode_matches_dense_and_ref(seed):
    rng = np.random.default_rng(200 + seed)
    h = int(rng.choice([4, 8]))
    r, rr = int(rng.choice([32, 64])), 16
    ps = int(rng.choice([16, 32]))
    tp = int(rng.choice([2, 4]))
    bucket = ps * tp
    dtype = jnp.float32 if seed % 3 else jnp.bfloat16
    b = 2
    pool_pages = b * tp + 2
    cp = jnp.asarray(rng.standard_normal((pool_pages, ps, r + rr)) * 0.3,
                     dtype)
    tables = np.asarray(rng.permutation(pool_pages)[: b * tp],
                        np.int32).reshape(b, tp)
    lens = np.asarray([int(rng.integers(1, bucket + 1)) for _ in range(b)],
                      np.int32)
    ql = jnp.asarray(rng.standard_normal((b, h, 1, r + rr)) * 0.3, dtype)

    out = ops.paged_mla_decode(ql, cp, tables, cache_len=jnp.asarray(lens),
                               kv_lora_rank=r, rope_head_dim=rr)
    cd = jnp.stack([jnp.concatenate([cp[t] for t in row], axis=0)
                    for row in tables])
    dense = ops.mla_decode(ql, cd, cache_len=jnp.asarray(lens),
                           kv_lora_rank=r, rope_head_dim=rr)
    tol = 1e-6 if dtype == jnp.float32 else TOL[dtype]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(dense, np.float32),
                               atol=tol, rtol=tol)
    gold = ref.mla_attention(ql, cd, rope_dim=rr, scale=(128 + rr) ** -0.5,
                             causal=False, kv_valid=jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype],
                               err_msg=f"ps={ps} tp={tp}")


# --------------------------------------------------------------------------
# int8-quantized pages (KV_QUANT): bounded-error parity with the fp pool
# --------------------------------------------------------------------------

# dequant error bound for unit-scale gaussian KV data: per-element int8
# absmax quantization is ≤ scale/2 ≈ amax/254, and the attention output is
# a convex combination of V rows — measured max err is ~1e-2, asserted at
# 5e-2 so the bound documents the contract without flaking
QTOL = 5e-2


def _quantize_pool(pool):
    """Per-page symmetric int8 absmax quantization of a float pool —
    the same math :func:`repro.models.attention.paged_scatter_quant`
    applies on write.  Returns ``(int8_pool, (P,) f32 scales)``."""
    p = np.asarray(pool, np.float32)
    flat = p.reshape(p.shape[0], -1)
    scale = np.abs(flat).max(axis=1) / 127.0
    q = np.clip(np.round(flat / np.maximum(scale, 1e-30)[:, None]),
                -127, 127).astype(np.int8).reshape(p.shape)
    return jnp.asarray(q), jnp.asarray(scale, jnp.float32)


@pytest.mark.parametrize("seed", range(6))
def test_paged_decode_int8_parity(seed):
    """int8 pools + per-page scales decode within the documented bound of
    the fp pool, across MHA/GQA/MQA, dtypes, permuted tables, and
    heterogeneous per-row lengths."""
    rng = np.random.default_rng(300 + seed)
    hq, hkv = [(4, 4), (8, 2), (4, 1)][seed % 3]
    dtype = jnp.bfloat16 if seed % 2 else jnp.float32
    d, ps, tp, b = 32, 32, 2, 2
    bucket = ps * tp
    kp, vp, tables, _, _ = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, tp=tp,
        pool_pages=b * tp + 2, dtype=dtype)
    ki, ks = _quantize_pool(kp)
    vi, vs = _quantize_pool(vp)
    lens = jnp.asarray([int(rng.integers(1, bucket + 1)) for _ in range(b)],
                       jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, dtype)
    fp = ops.paged_flash_decode(q, kp, vp, tables, cache_len=lens)
    qout = ops.paged_flash_decode(q, ki, vi, tables, cache_len=lens,
                                  kv_scales=(ks, vs))
    np.testing.assert_allclose(
        np.asarray(qout, np.float32), np.asarray(fp, np.float32),
        atol=QTOL, rtol=0,
        err_msg=f"int8 decode drift: Hq={hq} Hkv={hkv} dtype={dtype}")


def test_paged_decode_int8_split_kv_composes():
    """Forced split-KV over an int8 pool merges to the same answer as the
    sequential pass — the scale gather must be split-invariant."""
    rng = np.random.default_rng(17)
    b, hq, hkv, d, ps, tp = 2, 4, 2, 32, 16, 4
    kp, vp, tables, _, _ = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, tp=tp,
        pool_pages=b * tp + 2, dtype=jnp.float32)
    ki, ks = _quantize_pool(kp)
    vi, vs = _quantize_pool(vp)
    lens = jnp.asarray([49, 64], jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, jnp.float32)
    seq = ops.paged_flash_decode(q, ki, vi, tables, cache_len=lens,
                                 kv_scales=(ks, vs), num_splits=1)
    par = ops.paged_flash_decode(q, ki, vi, tables, cache_len=lens,
                                 kv_scales=(ks, vs), num_splits=2)
    np.testing.assert_allclose(np.asarray(par), np.asarray(seq),
                               atol=1e-5, rtol=1e-5)


def test_paged_int8_pallas_vs_jnp_oracle():
    """The Pallas kernel's per-page scale gather + dequant and the jnp
    oracle's must be the same function on a quantized TL program."""
    rng = np.random.default_rng(23)
    b, hq, hkv, d, ps, tp = 2, 4, 2, 32, 32, 2
    bucket = ps * tp
    kp, vp, tables, _, _ = _paged_case(
        rng, b=b, hq=hq, hkv=hkv, d=d, ps=ps, tp=tp,
        pool_pages=b * tp + 2, dtype=jnp.float32)
    ki, ks = _quantize_pool(kp)
    vi, vs = _quantize_pool(vp)
    lens = np.asarray([39, 64], np.int32)
    g = hq // hkv
    spec = AttnSpec(variant="mha", num_q_heads=hkv, num_kv_heads=hkv,
                    head_dim=d, causal=False, mode="decode", dtype="f32",
                    page_size=ps, kv_dtype="int8")
    kern = cached_kernel(spec, g, bucket, "v5e", True, False)
    assert kern.pallas_fn.kv_quant and kern.oracle_fn.kv_quant
    q = jnp.asarray(rng.standard_normal((b, hkv, g, d)) * 0.5, jnp.float32)
    qp = ops._pad_rows(q, 2, kern.blocks.bm)
    out = kern.pallas_fn(jnp.asarray(lens), jnp.asarray(tables), ks, vs,
                         qp, ki, vi)
    for bi in range(b):
        for h in range(hkv):
            o = kern.oracle_fn(int(lens[bi]), tables[bi], ks, vs, qp[bi, h],
                               ki[:, h].reshape(-1, d),
                               vi[:, h].reshape(-1, d))[:g]
            np.testing.assert_allclose(
                np.asarray(out[bi, h, :g], np.float32),
                np.asarray(o, np.float32), atol=1e-5, rtol=1e-5,
                err_msg=f"row {bi} kv-head {h}")


def test_paged_mla_decode_int8_parity():
    """MLA: the single latent pool quantizes with one scale vector."""
    rng = np.random.default_rng(31)
    b, h, r, rr, ps, tp = 2, 4, 64, 16, 16, 4
    bucket = ps * tp
    pool_pages = b * tp + 2
    cp = jnp.asarray(rng.standard_normal((pool_pages, ps, r + rr)) * 0.3,
                     jnp.float32)
    ci, cs = _quantize_pool(cp)
    tables = np.asarray(rng.permutation(pool_pages)[: b * tp],
                        np.int32).reshape(b, tp)
    lens = jnp.asarray([int(rng.integers(1, bucket + 1)) for _ in range(b)],
                       jnp.int32)
    ql = jnp.asarray(rng.standard_normal((b, h, 1, r + rr)) * 0.3,
                     jnp.float32)
    fp = ops.paged_mla_decode(ql, cp, tables, cache_len=lens,
                              kv_lora_rank=r, rope_head_dim=rr)
    qout = ops.paged_mla_decode(ql, ci, tables, cache_len=lens, c_scale=cs,
                                kv_lora_rank=r, rope_head_dim=rr)
    np.testing.assert_allclose(np.asarray(qout), np.asarray(fp),
                               atol=QTOL, rtol=0)


def test_kv_quant_spec_reason_roundtrip():
    """kv_dtype is a validated paged contract: KV_QUANT rides the TL
    params, the Allocate dtypes shrink to int8, and the printed program
    re-parses to the same quantized lowering."""
    from repro.core.tl import parse, to_text
    with pytest.raises(ValueError, match="page_size"):
        AttnSpec.mha(4, 32, mode="decode", causal=False, kv_dtype="int8")
    with pytest.raises(ValueError, match="unsupported"):
        AttnSpec.mha(4, 32, mode="decode", causal=False, page_size=32,
                     kv_dtype="fp4")
    spec = AttnSpec(variant="mha", num_q_heads=2, num_kv_heads=2,
                    head_dim=32, causal=False, mode="decode", page_size=32,
                    kv_dtype="int8")
    prog = reason_parameters(generate_sketch(spec), spec, q_len=8,
                             kv_len=128)
    assert prog.params["KV_QUANT"] == 1
    text = to_text(prog)
    assert "as int8" in text
    # print → parse → print is stable on the statements (header comments
    # carry the param env for humans and are not part of the AST)
    stmts = lambda t: [l for l in t.splitlines() if not l.startswith("//")]
    assert stmts(to_text(parse(text, name="rt"))) == stmts(text)


def test_one_kernel_per_quantized_bucket():
    """kv_scales are runtime data: every (cache_len, table, scale) draw
    within one capacity reuses one compiled quantized kernel, and the
    quantized spec keys a *separate* cache entry from the fp one (no
    silent cross-dtype reuse)."""
    rng = np.random.default_rng(41)
    b, hq, hkv, d, ps, tp = 1, 4, 2, 32, 32, 2
    kp = jnp.asarray(rng.standard_normal((6, hkv, ps, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((6, hkv, ps, d)), jnp.float32)
    ki, ks = _quantize_pool(kp)
    vi, vs = _quantize_pool(vp)
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
    tbl = np.asarray([[0, 1]], np.int32)
    ops.paged_flash_decode(q, ki, vi, tbl, cache_len=1,
                           kv_scales=(ks, vs))      # warm the capacity
    before = cached_kernel.cache_info()
    for cl in range(2, 20):
        t = np.asarray([rng.permutation(6)[:tp]], np.int32)
        ops.paged_flash_decode(q, ki, vi, t, cache_len=cl,
                               kv_scales=(ks, vs))
    after = cached_kernel.cache_info()
    assert after.misses == before.misses, (
        "quantized paged decode retraced inside one bucket")
    assert after.hits > before.hits


# --------------------------------------------------------------------------
# spec / reasoning invariants + bounded compilation
# --------------------------------------------------------------------------

def test_paged_spec_validation():
    with pytest.raises(ValueError, match="decode"):
        AttnSpec.mha(4, 32, mode="full", page_size=64)
    with pytest.raises(ValueError, match="multiple"):
        AttnSpec.mha(4, 32, mode="decode", causal=False, page_size=12)


def test_reasoning_aligns_bn_to_page_size():
    """The page size is a reasoned block parameter: BN must divide it so a
    KV tile never straddles a page boundary."""
    spec = AttnSpec(variant="mha", num_q_heads=2, num_kv_heads=2,
                    head_dim=32, causal=False, mode="decode", page_size=32)
    prog = reason_parameters(generate_sketch(spec), spec, q_len=8,
                             kv_len=128)
    assert prog.params["KV_PAGED"] == 1
    assert prog.params["PAGE_SIZE"] == 32
    bn = prog.params["BN"]
    assert 32 % bn == 0, f"BN={bn} does not divide page_size=32"
    assert prog.params["Tkv"] * bn == 128
    # capacity must be whole pages
    with pytest.raises(ReasonError, match="multiple"):
        reason_parameters(generate_sketch(spec), spec, q_len=8, kv_len=100)


def test_one_kernel_per_paged_bucket():
    """Every (cache_len, table permutation) within one capacity reuses one
    generated kernel — pools and tables are runtime data."""
    rng = np.random.default_rng(7)
    b, hq, hkv, d, ps, tp = 1, 4, 2, 32, 32, 2
    kp = jnp.asarray(rng.standard_normal((6, hkv, ps, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((6, hkv, ps, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
    ops.paged_flash_decode(q, kp, vp, np.asarray([[0, 1]], np.int32),
                           cache_len=1)          # warm the capacity
    before = cached_kernel.cache_info()
    for cl in range(2, 30):
        tbl = np.asarray([rng.permutation(6)[:tp]], np.int32)
        ops.paged_flash_decode(q, kp, vp, tbl, cache_len=cl)
    after = cached_kernel.cache_info()
    assert after.misses == before.misses, (
        "paged decode retraced the TL pipeline for runtime data (cache "
        "length / block table) inside an already-compiled bucket")
    assert after.hits > before.hits


# --------------------------------------------------------------------------
# hypothesis variants (skip when the test extra is not installed)
# --------------------------------------------------------------------------

@given(
    ps=st.sampled_from([16, 32, 64]),
    tp=st.sampled_from([1, 2, 4]),
    frac=st.floats(0.0, 1.0),
    geom=st.sampled_from([(4, 4), (8, 2), (4, 1), (6, 3)]),
    use_bf16=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=15, deadline=None)
def test_paged_decode_property(ps, tp, frac, geom, use_bf16, seed):
    """For any page geometry, cache fraction, head geometry and dtype:
    paged == dense on the logical cache the table encodes."""
    rng = np.random.default_rng(seed)
    hq, hkv = geom
    d = 32
    dtype = jnp.bfloat16 if use_bf16 else jnp.float32
    bucket = ps * tp
    cache_len = max(1, min(bucket, int(round(frac * bucket))))
    kp, vp, tables, kd, vd = _paged_case(
        rng, b=1, hq=hq, hkv=hkv, d=d, ps=ps, tp=tp, pool_pages=tp + 2,
        dtype=dtype)
    q = jnp.asarray(rng.standard_normal((1, hq, 1, d)) * 0.5, dtype)
    out = ops.paged_flash_decode(q, kp, vp, tables, cache_len=cache_len)
    dense = ops.flash_decode(q, kd, vd, cache_len=cache_len)
    tol = 1e-6 if dtype == jnp.float32 else TOL[dtype]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(dense, np.float32),
                               atol=tol, rtol=tol)
