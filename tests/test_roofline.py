"""Roofline HLO analysis: the parser's dot-FLOP counting (with while-trip
multipliers) is validated against analytically known workloads."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import analyze_hlo
from repro.roofline.report import model_flops
from repro.models import registry


def _costs_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(comp.as_text())


def test_single_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _costs_of(lambda x, y: x @ y, a, b)
    assert c.dot_flops == 2 * 128 * 256 * 512
    assert c.dot_bytes == 4 * (128 * 256 + 256 * 512 + 128 * 512)


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=13)
        return out

    c = _costs_of(f, x, w)
    assert c.dot_flops == 13 * 2 * 64 * 64 * 64
    assert c.num_whiles == 1


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    c = _costs_of(f, x, w)
    assert c.dot_flops == 3 * 5 * 2 * 32 ** 3


def test_batched_dot_flops():
    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    c = _costs_of(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
    assert c.dot_flops == 4 * 2 * 64 * 32 * 16


def test_model_flops_conventions():
    cfg = registry.get_config("deepseek-7b")
    sh = registry.SHAPES["train_4k"]
    mf = model_flops(cfg, sh)
    # 6*N*D dominates; must be within 2x of the bare product
    assert mf > 6 * cfg.param_count() * sh.global_batch * sh.seq_len * 0.9
    # MoE uses active params
    q = registry.get_config("qwen3-moe-235b-a22b")
    assert model_flops(q, sh) < 6 * q.param_count() * sh.global_batch * \
        sh.seq_len * 0.5
