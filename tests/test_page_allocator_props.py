"""Property tests for the ref-counted, prefix-indexed ``PageAllocator``.

The allocator is the serving engine's safety kernel: whatever interleaving
of submit (match + ref + alloc), decode growth (alloc), copy-on-write
(alloc + free), retire / preempt (free) and prefix registration occurs,

* pages are conserved — free + evictable + live == total (nothing leaks),
* a page is never double-freed (freeing an unheld page raises),
* the prefix index stays consistent with the refcount state, and
* eviction only ever reclaims refcount-0 pages.

The interleavings are hypothesis-generated op sequences interpreted
against the real allocator, with ``check_invariants()`` (the conservation
oracle) asserted after every single operation.  A short prompt alphabet
forces heavy prefix collisions so match/ref/COW paths are actually hit.
"""

import pytest

from hypothesis_compat import given, settings, st
from repro.serve import PageAllocator

PS = 8  # page size for the property runs


def _tokens(seed: int, length: int) -> list[int]:
    # alphabet of 3 + short lengths => dense prefix-collision space
    return [(seed + i * i) % 3 for i in range(length)]


def _run_interleaving(npages: int, ops: list[tuple[int, int]]) -> None:
    """Interpret an op sequence against a real allocator, asserting the
    conservation oracle after every operation.

    Every page a "request" writes also gets a distinctive absmax scale
    row (the int8-KV mirror): the run asserts scale rows follow page
    ownership exactly — a held page keeps the scale its writer set, a
    COW copy inherits its source's scale, and a freed / rolled-back page
    never leaves a stale row behind for the next owner to dequantize
    with (``check_invariants`` asserts free-list rows are zero)."""
    a = PageAllocator(npages, PS)
    holders: list[list] = []     # [pages, tokens] per live "request"
    myscale: dict[int, float] = {}     # page -> scale its writer recorded
    stamp = [0.0]

    def write_scales(pages):
        # a fresh page must arrive scale-0 (never the prior owner's row)
        for p in pages:
            assert a.scale_table[p] == 0.0, (
                f"page {p} handed out with a stale scale row")
        stamp[0] += 1.0
        a.set_scale(pages, [stamp[0]] * len(pages))
        for p in pages:
            myscale[p] = stamp[0]

    for code, arg in ops:
        if code == 0:
            # submit: probe the prefix cache, take ownership of the match,
            # allocate the rest all-or-nothing (engine admission contract)
            tlen = 1 + arg % (3 * PS)
            tokens = _tokens(arg, tlen)
            pages, mlen = a.match_prefix(tokens)
            mlen = min(mlen, tlen - 1)
            pages = pages[: a.pages_for(mlen) if mlen else 0]
            assert len(pages) * PS >= mlen
            a.ref(pages)
            fresh = a.alloc(a.pages_for(tlen) - len(pages))
            if fresh is None:
                a.free(pages)          # rollback: the request queues
            else:
                write_scales(fresh)
                for p in pages:        # adopt the cached pages' rows
                    myscale[p] = float(a.scale_table[p])
                holders.append([pages + fresh, tokens])
        elif code == 1 and holders:
            # decode growth: one more page for a growing cache
            h = holders[arg % len(holders)]
            got = a.alloc(1)
            if got is not None:
                write_scales(got)
                h[0].extend(got)
                h[1].extend(_tokens(arg, PS))
        elif code == 2 and holders:
            # retire / preempt: all pages returned (single decref each)
            pages, _ = holders.pop(arg % len(holders))
            a.free(pages)
        elif code == 3 and holders:
            # publish full pages to the prefix index
            h = holders[arg % len(holders)]
            a.register(h[1], h[0])
        elif code == 4 and holders:
            # copy-on-write: replace the first shared page we hold
            h = holders[arg % len(holders)]
            for i, p in enumerate(h[0]):
                if a.refcount(p) > 1:
                    got = a.alloc(1)
                    if got is not None:
                        # the fork duplicates content, so the copy
                        # dequantizes with the source page's scale; the
                        # source row itself must stay untouched for the
                        # remaining holders
                        assert a.scale_table[got[0]] == 0.0
                        a.copy_scale(p, got[0])
                        assert a.scale_table[got[0]] == a.scale_table[p]
                        myscale[got[0]] = float(a.scale_table[p])
                        a.free([p])
                        h[0][i] = got[0]
                    break
        elif code == 5 and holders:
            # speculative decode: draft pages allocated in a burst, the
            # accepted prefix optionally published to the index, and the
            # unaccepted tail rolled back to the pool the same step (the
            # engine's _grow_spec_pages / _rollback_pages pair).  Rolled-
            # back pages carry no committed tokens, so they are never
            # indexed — the free list must stay disjoint from the index.
            h = holders[arg % len(holders)]
            k = 1 + arg % 4
            got = a.alloc(k)
            if got is not None:
                write_scales(got)
                accept = (arg // 7) % (k + 1)
                h[0].extend(got)
                h[1].extend(_tokens(arg + 13, accept * PS))
                if (arg // 11) % 2:
                    a.register(h[1], h[0])   # publish committed pages
                tail = got[accept:]
                if tail:
                    a.free(tail)
                    del h[0][len(h[0]) - len(tail):]
        a.check_invariants()
        assert a.free_pages + a.live_pages == a.num_pages
        held = {p for h in holders for p in h[0]}
        for p in held:
            assert a.refcount(p) >= 1, "held page lost its refcount"
            assert a.scale_table[p] == myscale[p], (
                f"held page {p}'s scale row drifted (COW / rollback / "
                "free touched a live row)")

    # drain everything: the whole pool must come back
    for pages, _ in holders:
        a.free(pages)
    a.check_invariants()
    assert a.free_pages == a.num_pages
    assert a.live_pages == 0


@given(
    npages=st.integers(2, 12),
    ops=st.lists(st.tuples(st.integers(0, 5), st.integers(0, 2 ** 20)),
                 max_size=80),
)
@settings(max_examples=60, deadline=None)
def test_arbitrary_interleavings_conserve_pages(npages, ops):
    """Random submit/grow/COW/retire/register/spec-rollback interleavings
    never leak or double-free, the index never drifts from the refcount
    state, and a rolled-back page never stays matchable."""
    _run_interleaving(npages, ops)


@pytest.mark.parametrize("seed", range(25))
def test_seeded_interleavings_conserve_pages(seed):
    """Seeded variant of the interleaving property — runs (and keeps the
    invariants load-bearing) even where hypothesis is not installed."""
    import numpy as np

    rng = np.random.default_rng(seed)
    npages = int(rng.integers(2, 13))
    ops = [(int(rng.integers(0, 6)), int(rng.integers(0, 2 ** 20)))
           for _ in range(int(rng.integers(10, 80)))]
    _run_interleaving(npages, ops)


def test_double_free_and_bad_ref_raise():
    a = PageAllocator(4, PS)
    got = a.alloc(2)
    a.free([got[0]])
    with pytest.raises(ValueError, match="free"):
        a.free([got[0]])               # refcount already zero
    with pytest.raises(ValueError, match="free"):
        a.free([99])                   # never allocated
    with pytest.raises(ValueError, match="ref"):
        a.ref([got[0]])                # can't add holders to a free page
    a.free([got[1]])
    assert a.free_pages == 4


def test_shared_page_freed_once_per_holder():
    """A page with R holders leaves circulation after exactly R frees —
    the R+1-th raises."""
    a = PageAllocator(4, PS)
    (p,) = a.alloc(1)
    a.ref([p])
    a.ref([p])
    assert a.refcount(p) == 3
    a.free([p])
    a.free([p])
    assert a.refcount(p) == 1
    a.free([p])
    assert a.refcount(p) == 0 and a.free_pages == 4
    with pytest.raises(ValueError, match="free"):
        a.free([p])


def test_indexed_pages_park_then_revive_or_evict():
    """Refcount-0 indexed pages are evictable cache, still matchable;
    under pressure they are reclaimed LRU-first and leave the index."""
    a = PageAllocator(3, PS)
    toks = list(range(2 * PS))
    pages = a.alloc(2)
    a.register(toks, pages)
    a.free(pages)
    assert a.cached_pages == 2 and a.free_pages == 3
    # still matchable after the holder retired
    hit, mlen = a.match_prefix(toks)
    assert hit == pages and mlen == 2 * PS
    # revival: ref brings a cached page back to refcount 1
    a.ref(hit)
    assert a.refcount(pages[0]) == 1 and a.cached_pages == 0
    a.free(hit)
    # pressure: allocating the whole pool evicts the cache entries
    got = a.alloc(3)
    assert got is not None and a.evictions == 2
    assert a.match_prefix(toks) == ([], 0), "evicted pages must unindex"
    a.check_invariants()


def test_match_prefix_partial_page():
    """A prompt diverging mid-way through a cached page matches that page
    partially — the COW trigger case."""
    a = PageAllocator(4, PS)
    toks = list(range(2 * PS))
    pages = a.alloc(2)
    a.register(toks, pages)
    # identical first page; second page diverges after 3 tokens
    probe = toks[:PS + 3] + [777] * 4
    hit, mlen = a.match_prefix(probe)
    assert hit == pages and mlen == PS + 3
    # unindex of the sole-owner page removes it from future matches
    a.unindex(pages[1])
    hit, mlen = a.match_prefix(probe)
    assert hit == pages[:1] and mlen == PS
    a.free(pages)
    a.check_invariants()


def test_register_rejection_leaves_allocator_consistent():
    """A register over a free/invalid page raises *and* leaves the
    interned chain-node store clean — a rejected call must not poison
    later check_invariants runs (nodes interned before the raise are
    pruned on the error path)."""
    import pytest

    a = PageAllocator(4, PS)
    p1 = a.alloc(1)
    toks = list(range(2 * PS))
    with pytest.raises(ValueError, match="free/invalid"):
        a.register(toks, p1 + [99])    # page 99 was never allocated
    a.check_invariants()               # chunk 0 indexed, chunk 1 pruned
    hit, mlen = a.match_prefix(toks)
    assert hit == p1 and mlen == PS
    a.free(p1)
    a.check_invariants()


def test_register_resume_handle_skips_rewalk():
    """A growing request's resume handle registers each new boundary in
    O(page_size); a stale (pruned) handle falls back to the full walk
    with identical results."""
    a = PageAllocator(8, PS)
    toks = list(range(3 * PS))
    pages = a.alloc(3)
    h = a.register(toks[:PS], pages[:1])
    h = a.register(toks[:2 * PS], pages[:2], start=1, resume=h)
    h2 = a.register(toks, pages, start=2, resume=h)
    assert a.match_prefix(toks) == (pages, 3 * PS)
    # stale handle (bogus node id): silently re-walks from the root
    a.register(toks, pages, start=2, resume=(2, 10 ** 9))
    assert a.match_prefix(toks) == (pages, 3 * PS)
    assert h2[0] == 3
    a.free(pages)
    a.check_invariants()


def test_register_first_writer_wins():
    """Identical content arriving in a different page is not re-indexed —
    matches keep pointing at the original copy."""
    a = PageAllocator(4, PS)
    toks = list(range(PS))
    p1 = a.alloc(1)
    a.register(toks, p1)
    p2 = a.alloc(1)
    a.register(toks, p2)               # duplicate content, different page
    hit, mlen = a.match_prefix(toks + [1])
    assert hit == p1 and mlen == PS
    assert not a.is_indexed(p2[0])
    a.free(p1 + p2)
    a.check_invariants()
