"""Runtime-length decode kernels: parity with the jnp oracle / closed-form
reference, bucket invariance, and bounded compilation.

The contract under test (the PR's tentpole): decode-mode TL programs bind
``N`` to a *bucket capacity* and take the true cache length as a runtime
scalar operand, so one compiled kernel serves every ``cache_len`` within a
bucket — including per-request lengths in a heterogeneous batch.

Deterministic seeded sweeps always run; the hypothesis variants widen the
draw when the ``test`` extra is installed (see ``hypothesis_compat``).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core.pipeline import cached_kernel
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 1e-2}

_DT = {"bfloat16": "bf16", "float32": "f32"}


def _draw_case(seed: int):
    """One random (bucket, cache_len, head geometry, dtype) binding."""
    rng = np.random.default_rng(seed)
    bucket = int(rng.choice([64, 128, 256]))
    cache_len = int(rng.integers(1, bucket + 1))
    hq, hkv = [(4, 4), (8, 2), (4, 1), (6, 3)][rng.integers(0, 4)]  # MHA/GQA/MQA
    d = int(rng.choice([32, 64]))
    dtype = [jnp.float32, jnp.float32, jnp.bfloat16][rng.integers(0, 3)]
    return rng, bucket, cache_len, hq, hkv, d, dtype


def _decode_check(rng, bucket, cache_len, hq, hkv, d, dtype, b=2):
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, dtype)
    out = ops.flash_decode(q, k, v, cache_len=cache_len)
    gold = ref.decode_attention(q, k, v, cache_len=cache_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(gold, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
        err_msg=f"bucket={bucket} cache_len={cache_len} "
                f"Hq={hq} Hkv={hkv} D={d} {jnp.dtype(dtype).name}")
    return q, k, v, out


@pytest.mark.parametrize("seed", range(12))
def test_flash_decode_runtime_length_vs_ref(seed):
    """Random (bucket, cache_len ≤ bucket, geometry, dtype) draws: the
    runtime-length Pallas decode matches the closed-form reference."""
    _decode_check(*_draw_case(seed))


@pytest.mark.parametrize("seed", range(6))
def test_flash_decode_pallas_vs_jnp_oracle(seed):
    """Backend agreement on the same TL program: the Pallas kernel and the
    jnp oracle take the same runtime kv_len operand and must agree."""
    rng, bucket, cache_len, hq, hkv, d, dtype = _draw_case(seed)
    g = hq // hkv
    spec = AttnSpec(variant="mha", num_q_heads=hkv, num_kv_heads=hkv,
                    head_dim=d, causal=False, mode="decode",
                    dtype=_DT[jnp.dtype(dtype).name])
    kern = cached_kernel(spec, g, bucket, "v5e", True, False)
    assert kern.pallas_fn.runtime_kv_len and kern.oracle_fn.runtime_kv_len
    q = jnp.asarray(rng.standard_normal((1, hkv, g, d)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((1, hkv, bucket, d)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((1, hkv, bucket, d)) * 0.5, dtype)
    qp = ops._pad_rows(q, 2, kern.blocks.bm)
    out = kern.pallas_fn(cache_len, qp, k, v)[0, :, :g]
    for h in range(hkv):
        o = kern.oracle_fn(cache_len, qp[0, h], k[0, h], v[0, h])[:g]
        np.testing.assert_allclose(
            np.asarray(out[h], np.float32), np.asarray(o, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("seed", range(6))
def test_flash_decode_bucket_invariance(seed):
    """The answer must not depend on which bucket served the request: the
    same cache prefix decoded from a small and a large bucket agrees."""
    rng = np.random.default_rng(1000 + seed)
    hq, hkv, d = [(4, 4), (8, 2), (4, 1)][seed % 3], None, None
    hq, hkv = hq
    d = 32
    small, big = 128, 512
    cache_len = int(rng.integers(1, small + 1))
    q = jnp.asarray(rng.standard_normal((2, hq, 1, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, hkv, big, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, hkv, big, d)) * 0.5, jnp.float32)
    out_small = ops.flash_decode(q, k[:, :, :small], v[:, :, :small],
                                 cache_len=cache_len)
    out_big = ops.flash_decode(q, k, v, cache_len=cache_len)
    np.testing.assert_allclose(np.asarray(out_small, np.float32),
                               np.asarray(out_big, np.float32),
                               atol=1e-6, rtol=1e-6)


def test_flash_decode_per_request_lengths():
    """A (B,) cache_len vector masks each batch row at its own length —
    the serving engine's heterogeneous decode batches."""
    rng = np.random.default_rng(7)
    b, hq, hkv, d, bucket = 3, 8, 2, 64, 128
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, jnp.float32)
    lens = np.asarray([1, 57, 128], np.int32)
    out = ops.flash_decode(q, k, v, cache_len=jnp.asarray(lens))
    for i, cl in enumerate(lens):
        gold = ref.decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                    cache_len=int(cl))
        np.testing.assert_allclose(np.asarray(out[i:i + 1], np.float32),
                                   np.asarray(gold, np.float32),
                                   atol=1e-5, rtol=1e-5, err_msg=f"row {i}")


# --------------------------------------------------------------------------
# MLA
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_mla_decode_runtime_length_vs_ref(seed):
    rng = np.random.default_rng(2000 + seed)
    bucket = int(rng.choice([64, 128, 256]))
    cache_len = int(rng.integers(1, bucket + 1))
    h = int(rng.choice([4, 8, 16]))
    r, rr = int(rng.choice([32, 64])), 16
    dtype = jnp.float32 if seed % 3 else jnp.bfloat16
    ql = jnp.asarray(rng.standard_normal((2, h, 1, r + rr)) * 0.3, dtype)
    c = jnp.asarray(rng.standard_normal((2, bucket, r + rr)) * 0.3, dtype)
    out = ops.mla_decode(ql, c, cache_len=cache_len, kv_lora_rank=r,
                         rope_head_dim=rr)
    gold = ref.mla_attention(ql, c, rope_dim=rr, scale=(128 + rr) ** -0.5,
                             causal=False, kv_valid=cache_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype],
                               err_msg=f"bucket={bucket} len={cache_len}")


def test_mla_decode_bucket_invariance_and_per_row():
    rng = np.random.default_rng(9)
    h, r, rr, small, big = 8, 64, 16, 128, 256
    ql = jnp.asarray(rng.standard_normal((2, h, 1, r + rr)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((2, big, r + rr)) * 0.3, jnp.float32)
    a = ops.mla_decode(ql, c[:, :small], cache_len=100, kv_lora_rank=r,
                       rope_head_dim=rr)
    b_ = ops.mla_decode(ql, c, cache_len=100, kv_lora_rank=r,
                        rope_head_dim=rr)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               atol=1e-6, rtol=1e-6)
    lens = jnp.asarray([13, 222], jnp.int32)
    out = ops.mla_decode(ql, c, cache_len=lens, kv_lora_rank=r,
                         rope_head_dim=rr)
    for i, cl in enumerate([13, 222]):
        gold = ref.mla_attention(ql[i:i + 1], c[i:i + 1], rope_dim=rr,
                                 scale=(128 + rr) ** -0.5, causal=False,
                                 kv_valid=cl)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(gold, np.float32),
                                   atol=1e-5, rtol=1e-5, err_msg=f"row {i}")


# --------------------------------------------------------------------------
# bounded compilation at the kernel layer
# --------------------------------------------------------------------------

def test_one_kernel_per_bucket_capacity():
    """Every cache_len within one capacity reuses one generated kernel:
    the TL pipeline cache gains at most one entry however many lengths
    are decoded."""
    rng = np.random.default_rng(11)
    b, hq, hkv, d, bucket = 1, 4, 2, 32, 128
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, jnp.float32)
    ops.flash_decode(q, k, v, cache_len=1)          # warm the capacity
    before = cached_kernel.cache_info()
    for cl in range(2, 40):
        ops.flash_decode(q, k, v, cache_len=cl)
    after = cached_kernel.cache_info()
    assert after.misses == before.misses, (
        "decode retraced the TL pipeline for a cache length inside an "
        "already-compiled bucket")
    assert after.hits > before.hits


# --------------------------------------------------------------------------
# hypothesis variants (skip when the test extra is not installed)
# --------------------------------------------------------------------------

@given(
    bucket=st.sampled_from([64, 128, 256]),
    frac=st.floats(0.0, 1.0),
    geom=st.sampled_from([(4, 4), (8, 2), (4, 1), (6, 3)]),
    d=st.sampled_from([32, 64]),
    use_bf16=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=20, deadline=None)
def test_flash_decode_runtime_length_property(bucket, frac, geom, d,
                                              use_bf16, seed):
    rng = np.random.default_rng(seed)
    hq, hkv = geom
    cache_len = max(1, min(bucket, int(round(frac * bucket))))
    dtype = jnp.bfloat16 if use_bf16 else jnp.float32
    _decode_check(rng, bucket, cache_len, hq, hkv, d, dtype, b=1)


@given(
    frac=st.floats(0.0, 1.0),
    h=st.sampled_from([4, 8]),
    r=st.sampled_from([32, 64]),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=10, deadline=None)
def test_mla_decode_runtime_length_property(frac, h, r, seed):
    rng = np.random.default_rng(seed)
    bucket, rr = 128, 16
    cache_len = max(1, min(bucket, int(round(frac * bucket))))
    ql = jnp.asarray(rng.standard_normal((1, h, 1, r + rr)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((1, bucket, r + rr)) * 0.3, jnp.float32)
    out = ops.mla_decode(ql, c, cache_len=cache_len, kv_lora_rank=r,
                         rope_head_dim=rr)
    gold = ref.mla_attention(ql, c, rope_dim=rr, scale=(128 + rr) ** -0.5,
                             causal=False, kv_valid=cache_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold, np.float32),
                               atol=1e-5, rtol=1e-5)
