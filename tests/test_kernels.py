"""Per-kernel allclose sweeps: TL-Pallas kernel (interpret) vs the TL-jnp
oracle vs the closed-form reference, across shapes and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import generate_attention_kernel
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref
from repro.kernels.linear_scan import rwkv6_chunked

RNG = np.random.default_rng(42)


def rand(shape, dtype=jnp.float32, scale=0.5):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------------------
# flash attention sweep
# --------------------------------------------------------------------------
FLASH_CASES = [
    # (B, Hq, Hkv, M, N, D, causal, window, dtype)
    (1, 4, 4, 128, 128, 64, True, None, jnp.float32),
    (2, 8, 2, 128, 256, 64, True, None, jnp.float32),
    (1, 4, 1, 96, 160, 128, True, None, jnp.float32),     # MQA, ragged
    (2, 4, 2, 64, 64, 32, False, None, jnp.float32),
    (1, 4, 4, 256, 256, 64, True, 64, jnp.float32),       # sliding window
    (1, 8, 2, 128, 128, 128, True, None, jnp.bfloat16),
    (1, 2, 2, 37, 53, 64, True, None, jnp.float32),       # odd sizes
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=lambda c: f"B{c[0]}H{c[1]}kv{c[2]}M{c[3]}N{c[4]}D{c[5]}c{int(c[6])}w{c[7]}{jnp.dtype(c[8]).name}")
def test_flash_attention_vs_ref(case):
    b, hq, hkv, m, n, d, causal, window, dtype = case
    q = rand((b, hq, m, d), dtype)
    k = rand((b, hkv, n, d), dtype)
    v = rand((b, hkv, n, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    gold = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_three_way_agreement():
    """pallas == oracle == reference for the same TL program."""
    spec = AttnSpec.gqa(4, 2, 64, dtype="f32")
    kern = generate_attention_kernel(spec, 128, 128)
    q = rand((1, 4, 128, 64))
    k = rand((1, 2, 128, 64))
    v = rand((1, 2, 128, 64))
    o_pallas = kern.pallas_fn(q, k, v)
    o_oracle = kern.oracle_fn(q[0, 0], k[0, 0], v[0, 0])
    o_ref = ref.attention(q, k, v, causal=True)[0, 0]
    np.testing.assert_allclose(np.asarray(o_pallas[0, 0], np.float32),
                               np.asarray(o_ref), atol=2e-3)
    np.testing.assert_allclose(np.asarray(o_oracle, np.float32),
                               np.asarray(o_ref), atol=2e-5)


def test_block_size_invariance():
    """Different (BM, BN) choices give the same answer — parameters affect
    performance only (the paper's reasoning-stage contract)."""
    from repro.core.reason import BlockConfig
    spec = AttnSpec.mha(2, 64, dtype="f32")
    q, k, v = rand((1, 2, 256, 64)), rand((1, 2, 256, 64)), rand((1, 2, 256, 64))
    outs = []
    for bm, bn in [(32, 128), (64, 256), (128, 128), (256, 256)]:
        kern = generate_attention_kernel(spec, 256, 256,
                                         blocks=BlockConfig(bm, bn))
        outs.append(np.asarray(kern.pallas_fn(q, k, v), np.float32))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-5)


def test_causal_block_skip_matches_full():
    spec = AttnSpec.mha(2, 64, dtype="f32")
    q, k, v = rand((1, 2, 256, 64)), rand((1, 2, 256, 64)), rand((1, 2, 256, 64))
    a = generate_attention_kernel(spec, 256, 256, causal_block_skip=True)
    b_ = generate_attention_kernel(spec, 256, 256, causal_block_skip=False)
    np.testing.assert_allclose(np.asarray(a.pallas_fn(q, k, v), np.float32),
                               np.asarray(b_.pallas_fn(q, k, v), np.float32),
                               atol=1e-6)


# --------------------------------------------------------------------------
# MLA
# --------------------------------------------------------------------------
MLA_CASES = [
    (1, 4, 128, 128, 128, 32, jnp.float32),
    (2, 8, 64, 192, 64, 16, jnp.float32),
    (1, 16, 128, 128, 128, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", MLA_CASES,
                         ids=lambda c: f"B{c[0]}H{c[1]}M{c[2]}N{c[3]}R{c[4]}Rr{c[5]}{jnp.dtype(c[6]).name}")
def test_mla_vs_ref(case):
    b, h, m, n, r, rr, dtype = case
    ql = rand((b, h, m, r + rr), dtype, 0.3)
    c = rand((b, n, r + rr), dtype, 0.3)
    out = ops.mla_attention(ql, c, kv_lora_rank=r, rope_head_dim=rr)
    gold = ref.mla_attention(ql, c, rope_dim=rr, scale=(128 + rr) ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32),
                               atol=TOL[dtype] * 2, rtol=TOL[dtype])


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def test_flash_decode_vs_ref():
    b, hq, hkv, n, d = 2, 8, 2, 300, 64
    q = rand((b, hq, 1, d))
    kc, vc = rand((b, hkv, n, d)), rand((b, hkv, n, d))
    for cache_len in (1, 8, 257, 300):
        out = ops.flash_decode(q, kc, vc, cache_len=cache_len)
        gold = ref.decode_attention(q, kc, vc, cache_len=cache_len)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(gold, np.float32), atol=2e-5,
                                   err_msg=f"cache_len={cache_len}")


def test_mla_decode_vs_ref():
    b, h, n, r, rr = 2, 8, 160, 64, 16
    ql = rand((b, h, 1, r + rr), scale=0.3)
    c = rand((b, n, r + rr), scale=0.3)
    out = ops.mla_decode(ql, c, cache_len=100, kv_lora_rank=r,
                         rope_head_dim=rr)
    gold = ref.mla_attention(ql, c, rope_dim=rr, scale=(128 + rr) ** -0.5,
                             causal=False, kv_valid=100)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), atol=2e-5)


# --------------------------------------------------------------------------
# linear scan (RWKV-6)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 2, 64, 16, 16), (2, 4, 128, 32, 32),
                                   (1, 1, 256, 64, 64)])
def test_rwkv6_chunked_vs_sequential(shape):
    b, h, t, dk, dv = shape
    r, k = rand((b, h, t, dk), scale=0.3), rand((b, h, t, dk), scale=0.3)
    v = rand((b, h, t, dv), scale=0.3)
    w = rand((b, h, t, dk), scale=0.5) - 0.5
    u = rand((h, dk), scale=0.3)
    out = rwkv6_chunked(r, k, v, w, u, chunk=min(32, t))
    gold = ref.rwkv6_scan(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold), atol=5e-4, rtol=1e-3)


# --------------------------------------------------------------------------
# property: the XLA compile path agrees with the reference on random shapes
# --------------------------------------------------------------------------
from hypothesis_compat import given, settings, st


@given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    m=st.integers(1, 96),
    n=st.integers(1, 160),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    chunk=st.sampled_from([16, 64, 128]),
)
@settings(max_examples=25, deadline=None)
def test_xla_flash_property(b, hkv, g, m, n, d, causal, chunk):
    from repro.models.attention import xla_flash
    rng = np.random.default_rng(b * 1000 + m * 7 + n)
    hq = hkv * g
    q = jnp.asarray(rng.standard_normal((b, hq, m, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, n, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, n, d)) * 0.5, jnp.float32)
    out = xla_flash(q, k, v, causal=causal, scale=d ** -0.5, chunk=chunk)
    gold = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), atol=3e-5,
                               rtol=1e-4)
