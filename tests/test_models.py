"""Per-architecture smoke tests (reduced configs) + model-level behaviour:
forward shapes, finiteness, cached-prefill/decode consistency, gradients.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T

ARCHS = registry.list_archs()


def _setup(arch, B=2, S=16):
    cfg = registry.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    vis = None
    if cfg.cross_attn_period:
        vis = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.num_patches, cfg.vision_d))
    return cfg, params, toks, vis


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params, toks, vis = _setup(arch)
    logits, aux, _ = T.apply(params, toks, cfg, vision_embeds=vis)
    assert logits.shape == (*toks.shape, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_uncached_forward(arch):
    cfg, params, toks, vis = _setup(arch)
    logits, _, _ = T.apply(params, toks, cfg, vision_embeds=vis)
    caches = T.init_caches(cfg, toks.shape[0], 64)
    logits_c, _, _ = T.apply(params, toks, cfg, vision_embeds=vis,
                             caches=caches, cache_len=0)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_c, np.float32),
                               atol=3e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_parallel_forward(arch):
    """Token-by-token decode reproduces the teacher-forced logits."""
    cfg, params, toks, vis = _setup(arch, B=1, S=8)
    full_logits, _, _ = T.apply(params, toks, cfg, vision_embeds=vis)
    caches = T.init_caches(cfg, 1, 32)
    got = []
    for t in range(toks.shape[1]):
        lg, _, caches = T.apply(params, toks[:, t:t + 1], cfg,
                                vision_embeds=vis, caches=caches,
                                cache_len=t)
        got.append(lg[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                               np.asarray(got, np.float32),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b", "rwkv6-1.6b"])
def test_gradients_flow_everywhere(arch):
    """Every parameter receives a non-zero gradient somewhere."""
    cfg, params, toks, vis = _setup(arch, B=2, S=16)

    def loss(p):
        total, _ = T.loss_fn(p, {"tokens": toks, "labels": toks}, cfg,
                             vision_embeds=vis)
        return total

    g = jax.grad(loss)(params)
    flat = jax.tree_util.tree_flatten_with_path(g)[0]
    dead = [jax.tree_util.keystr(path) for path, leaf in flat
            if not bool(jnp.any(jnp.abs(leaf) > 0))]
    # router/aux paths can legitimately be zero on tiny batches; nothing else
    assert all("router" in d or "u" in d or "decay" in d for d in dead), dead


def test_tl_pallas_attention_impl_matches_xla_flash():
    """The TL-generated Pallas kernel slots into the model layer and agrees
    with the XLA compile path end-to-end."""
    cfg = registry.get_reduced("deepseek-7b")
    cfg_p = dataclasses.replace(cfg, attn_impl="tl_pallas", head_dim=16)
    cfg_x = dataclasses.replace(cfg, attn_impl="xla_flash", head_dim=16)
    params = T.init_params(jax.random.PRNGKey(0), cfg_p)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    lp, _, _ = T.apply(params, toks, cfg_p)
    lx, _, _ = T.apply(params, toks, cfg_x)
    np.testing.assert_allclose(np.asarray(lp, np.float32),
                               np.asarray(lx, np.float32), atol=2e-4,
                               rtol=1e-4)


def test_param_count_sanity():
    """Full configs land within ~25% of their published total params."""
    expected = {
        "deepseek-v2-lite-16b": 15.7e9,
        "qwen3-moe-235b-a22b": 235e9,
        "deepseek-7b": 7e9,
        "llama3-405b": 405e9,
        "mistral-nemo-12b": 12e9,
        "deepseek-coder-33b": 33e9,
        "musicgen-large": 3.3e9,   # MusicGen sizes: 300M/1.5B/3.3B
        "llama-3.2-vision-90b": 90e9,
        "jamba-1.5-large-398b": 398e9,
        "rwkv6-1.6b": 1.6e9,
    }
    for arch, want in expected.items():
        got = registry.get_config(arch).param_count()
        assert 0.6 * want < got < 1.45 * want, \
            f"{arch}: param_count {got/1e9:.1f}B vs published {want/1e9:.1f}B"


def test_moe_capacity_drops_are_bounded():
    from repro.models import moe as MOE
    cfg = registry.get_reduced("qwen3-moe-235b-a22b")
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    out, aux = MOE.moe_apply(p, x, cfg=cfg)
    assert out.shape == x.shape
    assert float(aux) > 0                      # balance loss active
    assert bool(jnp.isfinite(out).all())
    # at capacity_factor -> inf nothing is dropped: doubling capacity
    # changes nothing when the first capacity already held every token
    import dataclasses as dc
    big = dc.replace(cfg, capacity_factor=100.0)
    out_big, _ = MOE.moe_apply(p, x, cfg=big)
    bigger = dc.replace(cfg, capacity_factor=200.0)
    out_bigger, _ = MOE.moe_apply(p, x, cfg=bigger)
    np.testing.assert_allclose(np.asarray(out_big), np.asarray(out_bigger),
                               atol=2e-5, rtol=1e-4)


def test_nested_remat_scan_matches_flat():
    """sqrt-depth remat (remat_scan_groups) is numerically the flat scan."""
    cfg0 = registry.get_reduced("deepseek-7b")
    cfg1 = dataclasses.replace(cfg0, remat_scan_groups=2)
    params = T.init_params(jax.random.PRNGKey(0), cfg0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg0.vocab_size)
    l0, _, _ = T.apply(params, toks, cfg0)
    l1, _, _ = T.apply(params, toks, cfg1)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-6)
    g0 = jax.grad(lambda p: T.loss_fn(
        p, {"tokens": toks, "labels": toks}, cfg0)[0])(params)
    g1 = jax.grad(lambda p: T.loss_fn(
        p, {"tokens": toks, "labels": toks}, cfg1)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
