"""Split-KV decode (Flash-Decoding) through the TL stack.

The contract under test (this PR's tentpole): the reasoning stage may
partition a decode kernel's KV axis into ``NUM_SPLITS`` *parallel* slices
— each producing partial online-softmax state, LSE-merged by a combine
stage — and the result must be invariant to the partitioning: for every
head geometry (MHA/GQA/MQA/MLA), layout (dense + paged, permuted block
tables), dtype (f32/bf16) and per-row runtime length, forcing
``num_splits ∈ {1, 2, 3, 8}`` changes nothing but the launch.  The
heuristic itself is deterministic, and compile counts stay bounded by
(bucket, splits) keys.

Deterministic seeded sweeps always run; hypothesis variants widen the draw
when the ``test`` extra is installed (see ``hypothesis_compat``).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core.pipeline import cached_kernel
from repro.core.reason import (
    MAX_KV_SPLITS,
    ReasonError,
    choose_num_splits,
    reason_parameters,
    split_layout,
)
from repro.core.sketch import generate_sketch
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref
from repro.models.attention import gather_pages, xla_flash

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 1e-2}
SPLITS = (1, 2, 3, 8)

_DT = {"bfloat16": "bf16", "float32": "f32"}


# --------------------------------------------------------------------------
# the reasoned decision: split_layout + choose_num_splits
# --------------------------------------------------------------------------

def test_split_layout_clamps_and_fixes():
    """Whole-tile splits, page-aligned, never more splits than tiles —
    and the result is a fixed point, so reason and both backends derive
    the identical layout from the recorded NUM_SPLITS."""
    for tkv in (1, 2, 3, 4, 7, 8, 16, 64):
        for unit in (1, 2, 4):
            for req in (1, 2, 3, 5, 8, 100):
                ns, tps = split_layout(req, tkv, unit)
                assert ns >= 1 and tps >= 1
                assert tps % unit == 0, "split cuts a page"
                assert ns * tps >= tkv, "splits don't cover the KV axis"
                assert (ns - 1) * tps < tkv, "an entirely dead split"
                assert ns <= req, "more splits than requested"
                assert split_layout(ns, tkv, unit) == (ns, tps), \
                    "not a fixed point"


def test_choose_num_splits_deterministic():
    """The heuristic is a pure function of (mode, rows, bucket, page
    geometry, target): under-filled launches split toward the target's
    decode_parallelism, saturated launches don't, tiny caches can't."""
    # batch 1, one MLA latent head, long paged context: max splits
    assert choose_num_splits(rows=1, kv_len=2048, page_size=64) == 8
    # v5e wants 16 parallel programs: 4 rows -> 4 splits
    assert choose_num_splits(rows=4, kv_len=2048, page_size=64) == 4
    # a saturated launch never splits
    assert choose_num_splits(rows=16, kv_len=2048, page_size=64) == 1
    assert choose_num_splits(rows=64, kv_len=2048, page_size=64) == 1
    # short caches clamp to one page / lane tile per split
    assert choose_num_splits(rows=1, kv_len=64, page_size=64) == 1
    assert choose_num_splits(rows=1, kv_len=256, page_size=64) == 4
    assert choose_num_splits(rows=1, kv_len=256) == 2        # dense: LANE
    # the combine-overhead cap — it binds forced requests too, at every
    # clamp point (heuristic, explicit resolution, and the layout itself)
    assert choose_num_splits(rows=1, kv_len=1 << 20,
                             page_size=64) == MAX_KV_SPLITS
    from repro.core.reason import resolve_num_splits
    assert resolve_num_splits(32, rows=1, kv_len=1 << 20) == MAX_KV_SPLITS
    assert split_layout(32, 64)[0] == MAX_KV_SPLITS
    # only decode partitions the KV axis
    assert choose_num_splits(rows=1, kv_len=2048, page_size=64,
                             mode="chunk_prefill") == 1
    # a wider device splits harder at the same launch width
    assert choose_num_splits(rows=4, kv_len=2048, page_size=64,
                             target="v5p") == 8


def test_reason_emits_split_params():
    """reason_parameters records the KV_SPLIT marker and the *clamped*
    NUM_SPLITS; dense tiling shrinks BN to honour the request; paged
    splits stay whole-page; non-decode modes refuse."""
    spec = AttnSpec(variant="mha", num_q_heads=2, num_kv_heads=2,
                    head_dim=32, causal=False, mode="decode")
    prog = reason_parameters(generate_sketch(spec), spec, q_len=8,
                             kv_len=512, num_splits=4)
    assert prog.params["KV_SPLIT"] == 1
    assert prog.params["NUM_SPLITS"] == 4
    assert prog.params["Tkv"] >= 4, "BN did not shrink to honour splits"
    assert prog.meta["num_splits"] == 4
    # one split => no marker (the fused-epilogue launch)
    prog1 = reason_parameters(generate_sketch(spec), spec, q_len=8,
                              kv_len=512, num_splits=1)
    assert "KV_SPLIT" not in prog1.params
    assert "NUM_SPLITS" not in prog1.params
    # paged: splits clamp to whole pages
    pspec = AttnSpec(variant="mha", num_q_heads=2, num_kv_heads=2,
                     head_dim=32, causal=False, mode="decode", page_size=64)
    pprog = reason_parameters(generate_sketch(pspec), pspec, q_len=8,
                              kv_len=128, num_splits=8)
    assert pprog.params["NUM_SPLITS"] == 2          # 2 pages -> 2 splits
    cspec = AttnSpec(variant="mha", num_q_heads=2, num_kv_heads=2,
                     head_dim=32, mode="chunk_prefill", page_size=64)
    with pytest.raises(ReasonError, match="decode"):
        reason_parameters(generate_sketch(cspec), cspec, q_len=64,
                          kv_len=128, num_splits=2)


# --------------------------------------------------------------------------
# split invariance: dense runtime-length decode
# --------------------------------------------------------------------------

def _dense_case(seed: int):
    rng = np.random.default_rng(seed)
    bucket = int(rng.choice([128, 256]))
    hq, hkv = [(4, 4), (8, 2), (4, 1), (6, 3)][rng.integers(0, 4)]
    d = 32
    dtype = [jnp.float32, jnp.float32, jnp.bfloat16][rng.integers(0, 3)]
    b = 2
    lens = rng.integers(1, bucket + 1, size=b).astype(np.int32)
    lens[rng.integers(0, b)] = bucket       # always exercise a full row
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)) * 0.5, dtype)
    return q, k, v, jnp.asarray(lens), lens, dtype


@pytest.mark.parametrize("seed", range(4))
def test_flash_decode_split_invariance(seed):
    """Dense decode with per-row runtime lengths: every forced split
    count agrees with the sequential pass and the closed-form ref."""
    q, k, v, lens, lens_np, dtype = _dense_case(seed)
    outs = {ns: np.asarray(ops.flash_decode(q, k, v, cache_len=lens,
                                            num_splits=ns), np.float32)
            for ns in SPLITS}
    gold = np.asarray(ref.decode_attention(q, k, v, cache_len=lens),
                      np.float32)
    for ns in SPLITS[1:]:
        np.testing.assert_allclose(
            outs[ns], outs[1], atol=TOL[dtype], rtol=TOL[dtype],
            err_msg=f"splits={ns} vs 1 (lens={lens_np})")
    np.testing.assert_allclose(outs[SPLITS[-1]], gold, atol=TOL[dtype],
                               rtol=TOL[dtype])


def test_flash_decode_split_len_zero_row():
    """Idle serving slots decode at length 0: every split of that row is
    dead and the merge must still produce exact zeros, not NaNs."""
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((2, 4, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 256, 32)), jnp.float32)
    out = np.asarray(ops.flash_decode(
        q, k, v, cache_len=jnp.asarray([0, 256]), num_splits=8), np.float32)
    assert np.all(np.isfinite(out))
    assert np.abs(out[0]).max() == 0.0
    gold = ref.decode_attention(q[1:], k[1:], v[1:], cache_len=256)
    np.testing.assert_allclose(out[1:], np.asarray(gold, np.float32),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# split invariance: paged decode (permuted tables) + MLA
# --------------------------------------------------------------------------

def _paged_case(seed: int, mla: bool):
    rng = np.random.default_rng(seed)
    ps, tp = 32, int(rng.choice([4, 8]))
    bucket = ps * tp
    b, pool = 2, 2 * tp + 3
    dtype = [jnp.float32, jnp.bfloat16][rng.integers(0, 2)]
    lens = rng.integers(1, bucket + 1, size=b).astype(np.int32)
    lens[0] = bucket
    tables = np.stack([rng.permutation(pool)[:tp] for _ in range(b)]) \
        .astype(np.int32)
    if mla:
        h, r, rr = 8, 64, 32
        q = jnp.asarray(rng.standard_normal((b, h, 1, r + rr)) * 0.3, dtype)
        cp = jnp.asarray(rng.standard_normal((pool, ps, r + rr)) * 0.3,
                         dtype)
        return q, cp, tables, jnp.asarray(lens), dtype, (r, rr)
    hq, hkv, d = [(4, 2), (4, 1), (4, 4)][rng.integers(0, 3)], None, 32
    hq, hkv = hq
    kp = jnp.asarray(rng.standard_normal((pool, hkv, ps, d)) * 0.5, dtype)
    vp = jnp.asarray(rng.standard_normal((pool, hkv, ps, d)) * 0.5, dtype)
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, dtype)
    return q, (kp, vp), tables, jnp.asarray(lens), dtype, None


@pytest.mark.parametrize("seed", range(3))
def test_paged_decode_split_invariance(seed):
    """Paged decode through permuted block tables: forced splits agree
    with the sequential pass and with the dense gather reference."""
    q, (kp, vp), tables, lens, dtype, _ = _paged_case(seed, mla=False)
    outs = {ns: np.asarray(ops.paged_flash_decode(
        q, kp, vp, tables, cache_len=lens, num_splits=ns), np.float32)
        for ns in SPLITS}
    for ns in SPLITS[1:]:
        np.testing.assert_allclose(outs[ns], outs[1], atol=TOL[dtype],
                                   rtol=TOL[dtype],
                                   err_msg=f"paged splits={ns} vs 1")
    kd = jnp.asarray(gather_pages(kp, jnp.asarray(tables)), jnp.float32)
    vd = jnp.asarray(gather_pages(vp, jnp.asarray(tables)), jnp.float32)
    gold = np.asarray(ref.decode_attention(
        jnp.asarray(q, jnp.float32), kd, vd, cache_len=lens), np.float32)
    np.testing.assert_allclose(outs[SPLITS[-1]], gold, atol=TOL[dtype],
                               rtol=TOL[dtype])


@pytest.mark.parametrize("seed", range(2))
def test_mla_decode_split_invariance(seed):
    """MLA decode — the earliest split beneficiary (B launch programs):
    dense and paged latent caches, forced splits vs sequential vs ref."""
    rng = np.random.default_rng(100 + seed)
    b, h, r, rr, bucket = 2, 8, 64, 32, 256
    q = jnp.asarray(rng.standard_normal((b, h, 1, r + rr)) * 0.3,
                    jnp.float32)
    c = jnp.asarray(rng.standard_normal((b, bucket, r + rr)) * 0.3,
                    jnp.float32)
    lens = jnp.asarray([bucket // 3, bucket], jnp.int32)
    outs = {ns: np.asarray(ops.mla_decode(
        q, c, cache_len=lens, num_splits=ns, kv_lora_rank=r,
        rope_head_dim=rr), np.float32) for ns in SPLITS}
    for ns in SPLITS[1:]:
        np.testing.assert_allclose(outs[ns], outs[1], atol=1e-5, rtol=1e-5)
    gold = np.asarray(ref.mla_attention(
        q, c, causal=False, kv_valid=lens, rope_dim=rr,
        scale=(128 + rr) ** -0.5), np.float32)
    np.testing.assert_allclose(outs[1], gold, atol=1e-4, rtol=1e-4)
    # paged latent pool, permuted table
    qp, cp, tables, plens, dtype, (pr, prr) = _paged_case(200 + seed,
                                                          mla=True)
    pouts = {ns: np.asarray(ops.paged_mla_decode(
        qp, cp, tables, cache_len=plens, num_splits=ns, kv_lora_rank=pr,
        rope_head_dim=prr), np.float32) for ns in SPLITS}
    for ns in SPLITS[1:]:
        np.testing.assert_allclose(pouts[ns], pouts[1], atol=TOL[dtype],
                                   rtol=TOL[dtype],
                                   err_msg=f"paged MLA splits={ns}")


# --------------------------------------------------------------------------
# backend agreement on the same split TL program
# --------------------------------------------------------------------------

@pytest.mark.parametrize("num_splits", [2, 3])
def test_split_pallas_vs_jnp_oracle(num_splits):
    """The Pallas split grid + combine kernel and the jnp oracle's
    split/merge loop execute the same TL program and must agree."""
    rng = np.random.default_rng(42)
    hkv, g, d, bucket = 2, 4, 32, 256
    spec = AttnSpec(variant="mha", num_q_heads=hkv, num_kv_heads=hkv,
                    head_dim=d, causal=False, mode="decode", dtype="f32")
    kern = cached_kernel(spec, g, bucket, "v5e", True, False, num_splits)
    assert kern.num_splits > 1, "split request collapsed"
    assert kern.pallas_fn.num_splits == kern.oracle_fn.num_splits \
        == kern.num_splits
    q = jnp.asarray(rng.standard_normal((1, hkv, g, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, hkv, bucket, d)) * 0.5,
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, hkv, bucket, d)) * 0.5,
                    jnp.float32)
    qp = ops._pad_rows(q, 2, kern.blocks.bm)
    kp = ops._pad_rows(k, 2, kern.blocks.bn)
    vp = ops._pad_rows(v, 2, kern.blocks.bn)
    for cache_len in (1, 97, bucket):
        out = kern.pallas_fn(cache_len, qp, kp, vp)[0, :, :g]
        for h in range(hkv):
            o = kern.oracle_fn(cache_len, qp[0, h], kp[0, h], vp[0, h])[:g]
            np.testing.assert_allclose(
                np.asarray(out[h], np.float32), np.asarray(o, np.float32),
                atol=1e-5, rtol=1e-5,
                err_msg=f"cache_len={cache_len} head={h}")


def test_xla_flash_split_invariance():
    """The XLA scan backend's split fold (splits folded into the batch
    axis + LSE merge) is output-invariant too — one reasoned decision,
    two lowerings."""
    rng = np.random.default_rng(9)
    b, hq, hkv, d, n = 2, 8, 2, 32, 512
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, n, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, n, d)) * 0.5, jnp.float32)
    lens = jnp.asarray([0, 371], jnp.int32)
    base = np.asarray(xla_flash(q, k, v, causal=False, scale=d ** -0.5,
                                kv_valid=lens, chunk=64), np.float32)
    for ns in (2, 3, 8):
        out = np.asarray(xla_flash(q, k, v, causal=False, scale=d ** -0.5,
                                   kv_valid=lens, chunk=64, num_splits=ns),
                         np.float32)
        np.testing.assert_allclose(out, base, atol=1e-6, rtol=1e-6,
                                   err_msg=f"xla_flash splits={ns}")
    gold = np.asarray(ref.decode_attention(q[1:], k[1:], v[1:],
                                           cache_len=371), np.float32)
    np.testing.assert_allclose(base[1:], gold, atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# compile accounting
# --------------------------------------------------------------------------

def test_one_kernel_per_bucket_and_splits():
    """The TL pipeline compiles once per (bucket, splits): runtime data
    (cache length) never retraces, a new split count traces exactly one
    new kernel, and repeating a (bucket, splits) pair hits the cache."""
    rng = np.random.default_rng(3)
    b, hq, hkv, d, bucket = 1, 4, 2, 32, 256
    q = jnp.asarray(rng.standard_normal((b, hq, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, bucket, d)), jnp.float32)
    ops.flash_decode(q, k, v, cache_len=1, num_splits=2)   # warm the pair
    before = cached_kernel.cache_info()
    for cl in range(2, 40):
        ops.flash_decode(q, k, v, cache_len=cl, num_splits=2)
    mid = cached_kernel.cache_info()
    assert mid.misses == before.misses, \
        "split decode retraced for runtime cache lengths"
    assert mid.hits > before.hits
    ops.flash_decode(q, k, v, cache_len=5, num_splits=4)
    after = cached_kernel.cache_info()
    assert after.misses == mid.misses + 1, \
        "a new split count must cost exactly one new kernel"


# --------------------------------------------------------------------------
# serving engine: split choice is part of the decode jit key
# --------------------------------------------------------------------------

def test_engine_decode_key_tracks_splits():
    """The engine's decode jit key includes (batch, bucket, splits,
    paged-ness) and the compile counter must equal the distinct keys —
    the in-engine assertion that a reasoned split change (or a forced
    one) can never silently retrace.  Tokens are split-invariant."""
    import jax

    from repro.models import registry
    from repro.models import transformer as T
    from repro.serve import ServeEngine

    cfg = registry.get_reduced("deepseek-7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 12)))
               for _ in range(2)]

    auto = ServeEngine(cfg, params, max_batch=2, max_len=256)
    one = ServeEngine(cfg, params, max_batch=2, max_len=256, num_splits=1)
    r_auto = auto.generate(prompts, max_new_tokens=4)
    r_one = one.generate(prompts, max_new_tokens=4)
    assert np.array_equal(r_auto.tokens, r_one.tokens), \
        "split choice changed the sampled tokens"
    # the forced engine's keys record splits=1; re-running either engine
    # adds no keys and no compiles (the in-engine assertion enforces the
    # equality on every decode dispatch)
    assert all(k[2] == 1 for k in one._decode_keys)
    keys, compiles = len(auto._decode_keys), auto.decode_compiles
    assert compiles == keys
    auto.generate(prompts, max_new_tokens=4)
    assert auto.decode_compiles == compiles
    assert len(auto._decode_keys) == keys

    # the paged submit/step path keys separately (tables change the
    # pytree structure) and also tracks exactly
    for p in prompts:
        auto.submit(p, max_new_tokens=3)
    auto.run_until_drained()
    assert auto.decode_compiles == len(auto._decode_keys)
    assert any(k[3] for k in auto._decode_keys), "paged key not recorded"


# --------------------------------------------------------------------------
# hypothesis variants (skip when the test extra is not installed)
# --------------------------------------------------------------------------

@given(
    frac=st.floats(0.0, 1.0),
    geom=st.sampled_from([(4, 4), (8, 2), (4, 1), (6, 3)]),
    use_bf16=st.booleans(),
    ns=st.sampled_from([2, 3, 8]),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=10, deadline=None)
def test_split_invariance_property(frac, geom, use_bf16, ns, seed):
    """For any cache fraction, head geometry, dtype and split count:
    split decode == sequential decode == closed-form reference."""
    rng = np.random.default_rng(seed)
    hq, hkv = geom
    d, bucket = 32, 256
    dtype = jnp.bfloat16 if use_bf16 else jnp.float32
    cache_len = max(1, min(bucket, int(round(frac * bucket))))
    q = jnp.asarray(rng.standard_normal((1, hq, 1, d)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((1, hkv, bucket, d)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((1, hkv, bucket, d)) * 0.5, dtype)
    out_s = np.asarray(ops.flash_decode(q, k, v, cache_len=cache_len,
                                        num_splits=ns), np.float32)
    out_1 = np.asarray(ops.flash_decode(q, k, v, cache_len=cache_len,
                                        num_splits=1), np.float32)
    gold = np.asarray(ref.decode_attention(q, k, v, cache_len=cache_len),
                      np.float32)
    np.testing.assert_allclose(out_s, out_1, atol=TOL[dtype],
                               rtol=TOL[dtype])
    np.testing.assert_allclose(out_s, gold, atol=TOL[dtype],
                               rtol=TOL[dtype])
