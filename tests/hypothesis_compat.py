"""Degrade hypothesis-based property tests to skips when hypothesis is
absent.

The test extra (``pip install -e .[test]``) pins hypothesis, but the tier-1
suite must still *collect and pass* in environments without it — property
tests import ``given``/``settings``/``st`` from here instead of from
hypothesis, and when the real library is missing each ``@given`` test
becomes a single skipped test.
"""

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal environments
    HAVE_HYPOTHESIS = False

    class _Anything:
        """Stand-in for the strategies module: any attribute access, call,
        or composition yields another stand-in, so module-level strategy
        definitions (``st.sampled_from``, ``@st.composite``) still import.
        The stand-ins are never *executed* — every ``@given`` test skips."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Anything()

    def given(*_args, **_kwargs):
        def deco(fn):
            import pytest

            def skipper():
                pytest.skip("hypothesis not installed (pip install -e "
                            "'.[test]' enables property tests)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
