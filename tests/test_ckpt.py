"""Checkpoint fault-tolerance properties: atomic commit, integrity
verification, keep-last-k GC, restore-with-structure-check."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as C


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    C.save(str(tmp_path), 5, t)
    got = C.restore(str(tmp_path), 5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_tmp_litter(tmp_path):
    C.save(str(tmp_path), 3, _tree())
    C.save(str(tmp_path), 7, _tree())
    os.makedirs(tmp_path / "step_000000009.tmp-dead")  # crashed writer
    assert C.latest_step(str(tmp_path)) == 7


def test_corruption_detected(tmp_path):
    C.save(str(tmp_path), 1, _tree())
    leaf = tmp_path / "step_000000001" / "leaf_00000.npy"
    arr = np.load(leaf)
    arr.flat[0] += 1.0
    np.save(leaf, arr)
    with pytest.raises(IOError, match="hash mismatch"):
        C.restore(str(tmp_path), 1, _tree())


def test_structure_mismatch_rejected(tmp_path):
    C.save(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError, match="structure mismatch"):
        C.restore(str(tmp_path), 1, {"only": jnp.zeros(3)})


def test_gc_keep_last(tmp_path):
    for s in range(6):
        C.save(str(tmp_path), s, {"x": jnp.float32(s)})
    C.gc_keep_last(str(tmp_path), keep=2)
    left = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert left == ["step_000000004", "step_000000005"]


def test_manager_async_save_and_restore(tmp_path):
    mgr = C.CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t)
    mgr.wait()
    step, got = mgr.restore_latest(t)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_restore_with_reshard_dtype_cast(tmp_path):
    """restore() puts leaves onto the requested sharding/dtype (elastic
    restart path: new mesh shape -> new shardings)."""
    t = {"w": jnp.ones((8, 8), jnp.float32)}
    C.save(str(tmp_path), 0, t)
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    sh = {"w": jax.sharding.SingleDeviceSharding(jax.devices()[0])}
    got = C.restore(str(tmp_path), 0, like, shardings=sh)
    assert got["w"].sharding == sh["w"]
