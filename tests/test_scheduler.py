"""SLO-aware budgeted chunked-prefill scheduler (``prefill_budget``).

The contract under test: interleaving prompt chunks between decode steps
is *invisible to tokens* (greedy outputs identical to whole-prompt
admission, prefix cache on or off), bounded in compiled shapes, honest in
its metrics, and actually does the SLO thing — a short high-priority
prompt gets its first token while a long prompt is still mid-prefill,
priority classes order admission / budget spend / preemption, and
identical in-flight prompts dedup against the leader's published pages.
"""

import jax
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def gqa():
    cfg = registry.get_reduced("deepseek-7b")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def _mk(cfg, params, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 128)
    kw.setdefault("page_size", 16)
    kw.setdefault("num_pages", 40)
    return ServeEngine(cfg, params, **kw)


def _drain(engine, prompts, n=6, priorities=None, max_steps=400):
    uids = [engine.submit(list(p), max_new_tokens=n,
                          priority=0 if priorities is None else priorities[i])
            for i, p in enumerate(prompts)]
    done = engine.run_until_drained(max_steps=max_steps)
    by_uid = {r.uid: list(r.tokens) for r in done}
    return [by_uid[u] for u in uids], {r.uid: r for r in done}, uids


def _prompts(cfg, rng, lens):
    return [list(map(int, rng.integers(0, cfg.vocab_size, n)))
            for n in lens]


# --------------------------------------------------------------------------
# parity: budgeted interleaving == whole-prompt admission
# --------------------------------------------------------------------------

@pytest.mark.parametrize("prefix_cache", [True, False])
def test_interleaved_matches_whole_prompt_mixed_lengths(gqa, prefix_cache):
    """Tentpole invariant: the scheduler moves *when* prompt tokens are
    computed, never what anything generates.  Mixed lengths spanning
    several chunk boundaries, stepped manually with the allocator's
    conservation oracle asserted after every single step."""
    cfg, params = gqa
    rng = np.random.default_rng(31)
    prompts = _prompts(cfg, rng, [50, 13, 29])
    base, _, _ = _drain(_mk(cfg, params, prefix_cache=prefix_cache),
                        prompts)
    engine = _mk(cfg, params, prefix_cache=prefix_cache,
                 prefill_budget=16)
    uids = [engine.submit(list(p), max_new_tokens=6) for p in prompts]
    done = []
    for _ in range(400):
        done.extend(engine.step())
        engine.allocator.check_invariants()
        if not engine._queue and not engine.active_requests:
            break
    by_uid = {r.uid: list(r.tokens) for r in done}
    got = [by_uid[u] for u in uids]
    assert got == base, "interleaving changed the tokens"


def test_interleaved_matches_whole_prompt_across_budgets(gqa):
    """Any budget — smaller than a page, page-sized, several pages —
    produces the same tokens; only the step at which they land moves."""
    cfg, params = gqa
    rng = np.random.default_rng(32)
    prompts = _prompts(cfg, rng, [40, 7, 22])
    base, _, _ = _drain(_mk(cfg, params), prompts)
    for budget in (5, 16, 48):
        got, _, _ = _drain(_mk(cfg, params, prefill_budget=budget),
                           prompts)
        assert got == base, f"budget={budget} changed the tokens"


@given(
    lens=st.lists(st.integers(min_value=1, max_value=45), min_size=1,
                  max_size=3),
    budget=st.sampled_from([8, 16, 24]),
    prefix_cache=st.booleans(),
)
@settings(max_examples=10, deadline=None)
def test_interleaved_matches_whole_prompt_property(gqa, lens, budget,
                                                   prefix_cache):
    """Property form: any prompt-length mix x budget x cache setting is
    output-identical to whole-prompt admission, and the page pool is
    conserved afterwards."""
    cfg, params = gqa
    rng = np.random.default_rng(sum(lens) * 31 + budget)
    prompts = _prompts(cfg, rng, lens)
    base, _, _ = _drain(_mk(cfg, params, prefix_cache=prefix_cache),
                        prompts, n=4)
    engine = _mk(cfg, params, prefix_cache=prefix_cache,
                 prefill_budget=budget)
    got, _, _ = _drain(engine, prompts, n=4)
    assert got == base
    assert engine.allocator.free_pages == engine.num_pages - 1
    engine.allocator.check_invariants()


# --------------------------------------------------------------------------
# the SLO part: TTFT of a short prompt behind a long one
# --------------------------------------------------------------------------

def test_short_high_priority_first_token_lands_mid_long_prefill(gqa):
    """A 96-token prompt takes ceil(96/16) = 6 budgeted steps to prefill;
    an 8-token priority-1 prompt submitted alongside must get its first
    token on step 1 — while the long prompt is still chunking — instead
    of queueing behind the whole prefill.  Deterministic step-count
    TTFT, the benchmark asserts the wall-clock version."""
    cfg, params = gqa
    rng = np.random.default_rng(33)
    long_p, short_p = _prompts(cfg, rng, [96, 8])
    engine = _mk(cfg, params, prefill_budget=16, max_len=256)
    got, reqs, uids = _drain(engine, [long_p, short_p], n=4,
                             priorities=[0, 1])
    r_long, r_short = reqs[uids[0]], reqs[uids[1]]
    assert r_short.first_token_step == 1, (
        f"short prompt's first token must land on step 1, "
        f"got {r_short.first_token_step}")
    assert r_long.first_token_step == 6, (
        f"96 tokens / budget 16 = 6 chunked steps, "
        f"got {r_long.first_token_step}")
    # parity: neither request's tokens moved
    base, _, _ = _drain(_mk(cfg, params, max_len=256),
                        [long_p, short_p], n=4)
    assert got == base
    # a finished-prefill request decodes every step: perfect step TPOT
    s = engine.stats()
    assert s["tpot_steps"]["p50"] == 1.0
    assert s["ttft_steps"]["n"] == 2


def test_equal_priority_budget_is_fifo(gqa):
    """Within a priority class the budget is spent FIFO by admission:
    the earlier long prompt finishes prefill strictly before the later
    one gets any budget (no starvation *across* steps, strict order
    within one)."""
    cfg, params = gqa
    rng = np.random.default_rng(34)
    pa, pb = _prompts(cfg, rng, [48, 48])
    engine = _mk(cfg, params, prefill_budget=16, max_len=256)
    _, reqs, uids = _drain(engine, [pa, pb], n=2)
    ra, rb = reqs[uids[0]], reqs[uids[1]]
    assert ra.first_token_step == 3          # 48/16 chunks
    assert rb.first_token_step == 6          # budget freed only after A


# --------------------------------------------------------------------------
# priority classes: queue order and preemption victims
# --------------------------------------------------------------------------

def test_priority_orders_admission_queue(gqa):
    """A later-submitted priority-1 request is admitted before queued
    priority-0 requests (FIFO within a class)."""
    cfg, params = gqa
    rng = np.random.default_rng(35)
    busy, c0, c1, hi = _prompts(cfg, rng, [16, 16, 16, 16])
    engine = _mk(cfg, params, max_batch=1, max_len=64)
    engine.submit(busy, max_new_tokens=12)
    engine.step()                            # busy occupies the only slot
    u0 = engine.submit(c0, max_new_tokens=2)
    u1 = engine.submit(c1, max_new_tokens=2)
    uh = engine.submit(hi, max_new_tokens=2, priority=1)
    assert [r.uid for r in engine._queue] == [uh, u0, u1]
    done = engine.run_until_drained(max_steps=200)
    order = [r.uid for r in done]
    assert order.index(uh) < order.index(u0) < order.index(u1)


def test_preemption_picks_lowest_priority_not_youngest(gqa):
    """Under pool pressure the victim is the lowest priority class, even
    when a lower-seq (older) request — the old youngest-first rule would
    have evicted the young high-priority request instead."""
    cfg, params = gqa
    rng = np.random.default_rng(36)
    p_low, p_hi = _prompts(cfg, rng, [16, 16])
    engine = _mk(cfg, params, max_batch=2, max_len=64, num_pages=4)
    ul = engine.submit(p_low, max_new_tokens=12, priority=0)
    uh = engine.submit(p_hi, max_new_tokens=12, priority=1)
    engine.step()   # both admitted (1 page each), 1 free page; both grow:
    # low (older, processed first) takes the free page, high's growth
    # must evict *low* — the lowest class — despite low's older seq
    assert engine.preemptions >= 1
    assert [r.uid for r in engine._queue] == [ul]
    assert [r.uid for r in engine.active_requests] == [uh]
    done = engine.run_until_drained(max_steps=200)
    assert {r.uid for r in done} == {ul, uh}
    engine.allocator.check_invariants()


# --------------------------------------------------------------------------
# in-flight radix dedup
# --------------------------------------------------------------------------

def test_inflight_identical_prompts_dedup_published_pages(gqa):
    """Two identical 64-token prompts under a small budget: the leader
    publishes full pages as chunks land, the follower adopts them and
    recomputes only the final partial-progress page — saving whole-page
    prefill compute without changing a token."""
    cfg, params = gqa
    rng = np.random.default_rng(37)
    prompt = _prompts(cfg, rng, [64])[0]
    engine = _mk(cfg, params, max_batch=2, max_len=256,
                 prefill_budget=16)
    got, _, _ = _drain(engine, [prompt, prompt], n=4)
    assert got[0] == got[1]
    # the follower adopted the leader's first 3 pages (the 4th holds the
    # truncated last token and is never adoptable)
    assert engine.inflight_dedup_pages == 3
    assert engine.prefill_tokens == 64 + 16, (
        f"follower should recompute only its last page, prefilled "
        f"{engine.prefill_tokens} tokens total")
    # parity against a dedup-free engine
    base, _, _ = _drain(_mk(cfg, params, max_batch=2, max_len=256,
                            prefix_cache=False), [prompt, prompt], n=4)
    assert got == base
    engine.allocator.check_invariants()


# --------------------------------------------------------------------------
# metrics and compile accounting
# --------------------------------------------------------------------------

def test_stats_fields_and_reset(gqa):
    cfg, params = gqa
    rng = np.random.default_rng(38)
    engine = _mk(cfg, params, prefill_budget=16)
    _drain(engine, _prompts(cfg, rng, [20, 9]), n=5)
    s = engine.stats()
    assert s["finished"] == 2
    assert s["generated_tokens"] == 10
    for k in ("ttft_s", "ttft_steps", "tpot_s", "tpot_steps"):
        assert s[k]["n"] == 2
        assert s[k]["p50"] is not None and s[k]["p99"] >= s[k]["p50"] >= 0
    assert s["steps"] > 0
    assert s["decode_compiles"] >= 1 and s["prefill_compiles"] >= 1
    engine.reset_metrics()
    s2 = engine.stats()
    assert s2["finished"] == 0 and s2["ttft_s"]["n"] == 0
    assert s2["steps"] == 0 and s2["preemptions"] == 0
    # compile counters survive the reset — they key the jit caches
    assert s2["decode_compiles"] == s["decode_compiles"]
    # and the engine still serves after a reset
    got, _, _ = _drain(engine, _prompts(cfg, rng, [11]), n=3)
    assert len(got[0]) == 3
    assert engine.stats()["finished"] == 1


def test_interleaved_compiles_bounded_by_shapes(gqa):
    """Chunked interleaving must not leak per-position traces: chunk caps
    are page multiples ≤ prefill_chunk and decode keys on (batch, bucket,
    splits, paged) — N mixed-length prompts stay within the same shape
    budget as whole-prompt admission (the engine's internal
    decode_compiles == len(keys) assertion runs on every step)."""
    cfg, params = gqa
    rng = np.random.default_rng(39)
    engine = _mk(cfg, params, prefill_budget=16)
    lens = [3, 17, 31, 18, 45, 9, 33, 27]
    for p in _prompts(cfg, rng, lens):
        engine.submit(p, max_new_tokens=3)
    engine.run_until_drained(max_steps=400)
    # caps: 16/32/48-token chunks x one kv bucket reachable at 128 max_len
    assert engine.prefill_compiles <= 8
    assert engine.decode_compiles <= 2
