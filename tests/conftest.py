import os
import sys

# smoke tests / benches see the single real CPU device; ONLY dryrun.py sets
# the 512-device flag (per instructions).  A couple of distributed tests
# need >1 device; they spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
