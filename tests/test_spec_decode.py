"""Speculative decoding: n-gram drafts + the TL verify mode + rollback.

The load-bearing contract is *token identity*: a spec engine commits
exactly the stream non-speculative greedy decode produces — for every
head layout (GQA / MQA / MLA), in bf16, with permuted page tables, and
when the draft source is pure garbage (zero acceptance).  On top of that
the suite locks the verify compile-key accounting (no silent retrace),
the draft/accept/rollback counters and their reset, and the engine gates
(recurrent / MoE / dense turn the flag off).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T
from repro.serve import NgramProposer, ServeEngine, make_proposer
from repro.serve.draft import DraftProposer


def _params(cfg):
    return T.init_params(jax.random.PRNGKey(0), cfg)


def _prompts(cfg, rng, repetitive=True):
    """A mix the drafts can bite on: repetitive prompts (n-gram lookup
    hits) plus one random prompt (drafts mostly miss)."""
    base = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
    out = [base * 6, base * 3 + [7]] if repetitive else []
    out.append(list(map(int, rng.integers(0, cfg.vocab_size, 23))))
    return out


def _run(cfg, params, prompts, *, spec, new=24, check=True, **kw):
    kw.setdefault("page_size", 16)
    eng = ServeEngine(cfg, params, max_batch=len(prompts), max_len=256,
                      spec_decode=spec, **kw)
    uids = [eng.submit(list(p), max_new_tokens=new) for p in prompts]
    done = eng.run_until_drained(max_steps=4000)
    by = {r.uid: r for r in done}
    if check and eng._allocator is not None:
        eng._allocator.check_invariants()
    return [by[u].tokens for u in uids], eng


CASES = {
    "gqa": lambda: registry.get_reduced("deepseek-7b"),
    "mqa": lambda: registry.get_reduced("deepseek-7b", num_kv_heads=1),
    "mla": lambda: registry.get_reduced("deepseek-v2-lite-16b", moe=False),
    "bf16": lambda: registry.get_reduced("deepseek-7b", dtype="bf16"),
}


@pytest.mark.parametrize("case", sorted(CASES))
def test_spec_decode_matches_greedy_stream(case):
    """Spec and non-spec engines commit identical greedy tokens across
    head layouts and dtypes; drafts actually fire (the repetitive
    prompts would be a vacuous pass otherwise)."""
    cfg = CASES[case]()
    params = _params(cfg)
    prompts = _prompts(cfg, np.random.default_rng(0))
    ref, _ = _run(cfg, params, prompts, spec=False)
    got, eng = _run(cfg, params, prompts, spec=True, draft_k=6)
    assert got == ref
    s = eng.stats()
    assert s["drafted_tokens"] > 0
    assert 0 < s["accepted_tokens"] <= s["drafted_tokens"]
    # acceptance shortened the run: fewer steps than tokens generated
    # by the longest request
    assert s["steps"] < 24 + len(prompts)


def test_spec_decode_permuted_page_tables():
    """Token identity survives a scrambled free list: a warm-up wave
    allocates and retires pages first, so the measured requests' tables
    are permuted and non-contiguous relative to the non-spec run."""
    cfg = CASES["gqa"]()
    params = _params(cfg)
    rng = np.random.default_rng(1)
    prompts = _prompts(cfg, rng)
    warm = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
            for n in (37, 19, 52)]
    ref, _ = _run(cfg, params, prompts, spec=False)

    eng = ServeEngine(cfg, params, max_batch=3, max_len=256, page_size=16,
                      spec_decode=True, draft_k=6)
    for p in warm:
        eng.submit(p, max_new_tokens=9)
    eng.run_until_drained(max_steps=2000)
    uids = [eng.submit(list(p), max_new_tokens=24) for p in prompts]
    done = {r.uid: r for r in eng.run_until_drained(max_steps=4000)}
    assert [done[u].tokens for u in uids] == ref
    eng._allocator.check_invariants()


class _GarbageProposer:
    """Worst-case draft source: always proposes out-of-distribution
    tokens, so every draft is rejected (zero acceptance)."""

    def __init__(self, vocab):
        self.vocab = vocab

    def propose(self, uid, history, k):
        return [(history[-1] + 1 + i) % self.vocab for i in range(k)]


def test_zero_acceptance_still_matches_and_rolls_back():
    """All-rejected drafts degrade to plain greedy decode — same tokens,
    acceptance p50/p99 == 0, and every draft page rolled back."""
    cfg = CASES["gqa"]()
    params = _params(cfg)
    prompts = _prompts(cfg, np.random.default_rng(2))
    ref, ref_eng = _run(cfg, params, prompts, spec=False)
    got, eng = _run(cfg, params, prompts, spec=True, draft_k=6,
                    draft_proposer=_GarbageProposer(cfg.vocab_size))
    assert got == ref
    s = eng.stats()
    assert s["drafted_tokens"] > 0 and s["accepted_tokens"] == 0
    assert s["acceptance_rate"]["p50"] == 0.0
    assert s["acceptance_rate"]["p99"] == 0.0
    assert s["rollback_pages"] > 0
    # zero acceptance commits one token per step, exactly like non-spec
    assert s["steps"] == ref_eng.stats()["steps"]


def test_verify_compile_keys_bounded():
    """The no-silent-retrace contract extends to verify: compiles equal
    the distinct (batch, cap, bucket, splits, paged) keys, and a long
    generation stays within O(buckets) traces."""
    cfg = CASES["gqa"]()
    params = _params(cfg)
    prompts = _prompts(cfg, np.random.default_rng(3))
    _, eng = _run(cfg, params, prompts, spec=True, draft_k=4, new=48)
    assert eng.verify_compiles == len(eng._verify_keys)
    assert eng.verify_compiles <= 3      # buckets touched, not steps
    # no-draft steps fall back to the decode shape — same contract there
    assert eng.decode_compiles == len(eng._decode_keys)
    caps = {k[1] for k in eng._verify_keys}
    assert caps == {eng.draft_k + 1}


def test_spec_counters_reset():
    """reset_metrics zeroes the draft/accept/rollback counters and the
    acceptance samples but keeps the compile accounting (warm-up wave
    contract)."""
    cfg = CASES["gqa"]()
    params = _params(cfg)
    prompts = _prompts(cfg, np.random.default_rng(4))
    _, eng = _run(cfg, params, prompts, spec=True, draft_k=6)
    s = eng.stats()
    assert s["drafted_tokens"] > 0 and s["acceptance_rate"]["n"] > 0
    compiles = s["verify_compiles"]
    assert compiles > 0
    eng.reset_metrics()
    s = eng.stats()
    assert s["drafted_tokens"] == 0 and s["accepted_tokens"] == 0
    assert s["rollback_pages"] == 0
    assert s["acceptance_rate"] == {"n": 0, "p50": None, "p99": None,
                                    "mean": None}
    assert s["verify_compiles"] == compiles


@pytest.mark.parametrize("case", ["gqa", "mqa", "mla"])
def test_spec_decode_with_int8_kv_pages(case):
    """kv_quant composes with speculation: given the *same* int8 cache
    numerics, drafting/verify/rollback must stay lossless — the spec
    engine commits the token stream the non-spec int8 engine commits.
    (Identity against the fp engine is deliberately not asserted: the
    documented contract is bounded dequant error, and a bounded error may
    flip an argmax on random-weight models — the kernel parity suites
    bound the numerics.)  Drafts still fire, and the allocator's
    scale-table invariant holds after the draft/verify/rollback churn."""
    cfg = CASES[case]()
    params = _params(cfg)
    prompts = _prompts(cfg, np.random.default_rng(7))
    ref, _ = _run(cfg, params, prompts, spec=False, kv_quant=True)
    got, eng = _run(cfg, params, prompts, spec=True, draft_k=6,
                    kv_quant=True)
    assert got == ref
    assert eng.kv_quant
    s = eng.stats()
    assert s["drafted_tokens"] > 0 and s["accepted_tokens"] > 0
    # device scale leaves exist and carry real (grown) scales
    blocks = eng._slot_caches["blocks"]
    names = {"cs"} if case == "mla" else {"ks", "vs"}
    kind = next(k for k, v in blocks.items()
                if isinstance(v, dict) and names <= set(v))
    for nm in names:
        assert float(np.max(np.asarray(blocks[kind][nm]))) > 0.0
    eng._allocator.check_invariants()    # free pages hold no stale scale


def test_kv_quant_gates_off_dense():
    """kv_quant is a paged-pool contract — a dense engine silently turns
    it off (mirroring prefix_cache), and init_caches refuses the combo
    outright."""
    cfg = CASES["gqa"]()
    eng = ServeEngine(cfg, _params(cfg), max_batch=1, max_len=64,
                      paged=False, kv_quant=True)
    assert not eng.kv_quant
    with pytest.raises(ValueError, match="paged"):
        T.init_caches(cfg, 1, 64, kv_quant=True)


def test_reset_metrics_clears_workload_counters():
    """The acceptance criterion of the metrics bugfix: warm-up wave →
    ``reset_metrics`` → measured wave reports exactly the workload
    counters a fresh engine reports for the same wave — prefix hit rates
    and prefill totals no longer inherit warm-up traffic.  Compile
    counters are the documented exception (the warm engine's whole
    point is reporting zero *fresh* compiles)."""
    cfg = CASES["gqa"]()
    params = _params(cfg)
    rng = np.random.default_rng(8)
    base = list(map(int, rng.integers(0, cfg.vocab_size, 4)))
    wave = [(base * 12)[:44], (base * 12)[:44],
            list(map(int, rng.integers(0, cfg.vocab_size, 30)))]
    warm = [list(map(int, 1 + rng.integers(0, cfg.vocab_size - 1, n)))
            for n in (21, 40)]

    def drive(eng, prompts):
        for p in prompts:
            eng.submit(list(p), max_new_tokens=12)
        eng.run_until_drained(max_steps=4000)

    kw = dict(max_batch=3, max_len=256, page_size=16, spec_decode=True,
              draft_k=4)
    warmed = ServeEngine(cfg, params, **kw)
    drive(warmed, warm)
    assert warmed.stats()["prefill_tokens"] > 0   # warm-up left residue
    warmed.reset_metrics()
    # the warm engine keeps its *evictable* prefix pages; drop them so the
    # measured wave sees the same cold index a fresh engine sees
    for p in list(warmed._allocator._evictable):
        warmed._allocator.unindex(p)
    warmed._allocator.check_invariants()
    drive(warmed, wave)

    fresh = ServeEngine(cfg, params, **kw)
    drive(fresh, wave)

    got, want = warmed.stats(), fresh.stats()
    # wall-clock percentiles are nondeterministic; compile counters are
    # the documented survivors of reset_metrics
    skip = {"ttft_s", "tpot_s", "prefill_compiles", "decode_compiles",
            "verify_compiles"}
    for k in set(want) - skip:
        assert got[k] == want[k], f"stale counter after reset: {k}"
    assert got["prefix_hits"] > 0        # the wave itself shares a prefix
    assert got["prefill_tokens"] > 0


def test_spec_gates_off_where_unsound():
    """Recurrent state cannot roll back, MoE routing couples drafts into
    committed numerics, and a dense engine has no pages to roll back —
    the flag silently turns off (mirroring prefix_cache's gates)."""
    for arch, kw in [("rwkv6-1.6b", {}),
                     ("deepseek-v2-lite-16b", {}),     # MoE
                     ("deepseek-7b", {"paged": False})]:
        cfg = registry.get_reduced(arch)
        eng = ServeEngine(cfg, _params(cfg), max_batch=1, max_len=64,
                          spec_decode=True, **kw)
        assert not eng.spec_decode, (arch, kw)
    with pytest.raises(ValueError, match="draft_k"):
        cfg = registry.get_reduced("deepseek-7b")
        ServeEngine(cfg, _params(cfg), max_batch=1, max_len=64,
                    spec_decode=True, draft_k=0)


def test_spec_respects_max_new_tokens_and_temperature():
    """Drafts never overshoot a request's budget, and temperature > 0
    rows ride the verify dispatch undrafted (their sampled stream is
    untouched by speculation)."""
    cfg = CASES["gqa"]()
    params = _params(cfg)
    rng = np.random.default_rng(5)
    base = list(map(int, rng.integers(0, cfg.vocab_size, 4)))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=256, page_size=16,
                      spec_decode=True, draft_k=6)
    u_greedy = eng.submit(base * 7, max_new_tokens=5)
    u_temp = eng.submit(base * 7, max_new_tokens=5, temperature=0.8)
    done = {r.uid: r for r in eng.run_until_drained(max_steps=2000)}
    assert len(done[u_greedy].tokens) == 5
    assert len(done[u_temp].tokens) == 5


def test_ngram_proposer_prompt_lookup():
    """Longest tail n-gram wins; within an n the most recent earlier
    occurrence wins; no match proposes nothing."""
    p = NgramProposer(max_n=3, min_n=1)
    #           0  1  2  3  4  5  6  7
    history = [1, 2, 3, 9, 1, 2, 3, 9, 1, 2, 3]
    assert p.propose(0, history, 4) == [9, 1, 2, 3]
    # most recent occurrence of the tail 1-gram [5]
    assert p.propose(0, [5, 7, 5, 8, 5], 2) == [8, 5]
    assert p.propose(0, [1, 2, 3], 4) == []      # nothing repeats
    assert p.propose(0, [1], 4) == []            # history too short
    assert isinstance(p, DraftProposer)
    assert isinstance(make_proposer("ngram", max_n=2), NgramProposer)
    with pytest.raises(ValueError, match="unknown draft proposer"):
        make_proposer("bigmodel")


def test_spec_with_prefix_cache_and_interleaving():
    """Speculation composes with the rest of the scheduler: budgeted
    chunked prefill, prefix sharing between requests, and the multi-token
    commit's own page publication all preserve token identity."""
    cfg = CASES["gqa"]()
    params = _params(cfg)
    rng = np.random.default_rng(6)
    base = list(map(int, rng.integers(0, cfg.vocab_size, 4)))
    prompts = [(base * 12)[:44], (base * 12)[:44],
               list(map(int, rng.integers(0, cfg.vocab_size, 30)))]
    ref, _ = _run(cfg, params, prompts, spec=False, prefill_budget=16)
    got, eng = _run(cfg, params, prompts, spec=True, draft_k=4,
                    prefill_budget=16)
    assert got == ref
    assert eng.stats()["accepted_tokens"] > 0
