"""End-to-end behaviour: train -> checkpoint -> crash-restore -> serve,
exercising the public entry points the way a deployment would."""

import os

import jax
import numpy as np
import pytest

from repro.launch.train import run as train_run
from repro.ckpt import checkpoint as C

# the end-to-end train loops take tens of seconds each on CPU; tier-1
# excludes them by default (`pytest -m slow` / `pytest -m ""` opts in)


@pytest.mark.slow
def test_train_loss_decreases_and_checkpoints(tmp_path):
    losses = train_run("deepseek-7b", reduced=True, steps=12, batch=8,
                       seq=64, ckpt_dir=str(tmp_path), ckpt_every=5,
                       lr=3e-3)
    assert losses[-1] < losses[0], f"no learning: {losses[0]} -> {losses[-1]}"
    assert C.latest_step(str(tmp_path)) == 12


@pytest.mark.slow
def test_restart_resumes_from_checkpoint(tmp_path):
    train_run("rwkv6-1.6b", reduced=True, steps=6, batch=4, seq=32,
              ckpt_dir=str(tmp_path), ckpt_every=3, lr=1e-3)
    assert C.latest_step(str(tmp_path)) == 6
    # simulated preemption: a new process picks up at step 6 and continues
    losses2 = train_run("rwkv6-1.6b", reduced=True, steps=9, batch=4,
                        seq=32, ckpt_dir=str(tmp_path), ckpt_every=3,
                        lr=1e-3)
    assert C.latest_step(str(tmp_path)) == 9
    assert len(losses2) == 3  # only steps 6..8 were run


@pytest.mark.slow
def test_grad_accum_equivalence():
    """grad_accum=2 over the same global batch matches accum=1 closely."""
    l1 = train_run("musicgen-large", reduced=True, steps=3, batch=8,
                   seq=32, grad_accum=1, lr=1e-3)
    l2 = train_run("musicgen-large", reduced=True, steps=3, batch=8,
                   seq=32, grad_accum=2, lr=1e-3)
    np.testing.assert_allclose(l1, l2, rtol=2e-3)


@pytest.mark.interpret
def test_serve_with_tl_pallas_attention():
    """The TL-generated Pallas kernels drive inference end-to-end (the
    TL pipeline emits forward kernels; training uses the same math via the
    differentiable xla_flash path)."""
    import dataclasses
    from repro.models import registry, transformer as T
    from repro.serve import ServeEngine

    cfg = dataclasses.replace(registry.get_reduced("musicgen-large"),
                              attn_impl="tl_pallas")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64)
    res = engine.generate([[1, 2, 3, 4], [5, 6, 7, 8]], max_new_tokens=4)
    assert res.tokens.shape == (2, 4)
    # agrees with the xla_flash engine
    cfg2 = dataclasses.replace(cfg, attn_impl="xla_flash")
    engine2 = ServeEngine(cfg2, params, max_batch=2, max_len=64)
    res2 = engine2.generate([[1, 2, 3, 4], [5, 6, 7, 8]], max_new_tokens=4)
    np.testing.assert_array_equal(res.tokens, res2.tokens)
