"""TL language tests: parsing, printing, round-trip property."""

import pytest
from hypothesis_compat import given, settings, st

from repro.core.tl.ast import (
    Allocate, ComputeGEMM, ComputeOp, Copy, ForLoop, MemSpace, Reshape,
    TensorRef, TLProgram,
)
from repro.core.tl.parser import TLSyntaxError, parse
from repro.core.tl.printer import to_text


def test_parse_paper_listing_fragments():
    # statements taken verbatim from the paper's listings/prompts
    prog = parse("""
Allocate A in global (M, K) with offset batch_offset
Copy A from global to shared
Copy Q (BM, HeadDim) in coordinate [L = block_idx] from global to shared memory
Compute GEMM Q_shared, K_shared.T and get S
Compute Softmax S
Reshape rS from mma_C to mma_A
Compute GEMM S, V_shared and accumulate O_register
for i = 0:N
    Copy K (BN, HeadDim) in coordinate [L = i+1] from global to shared
end
""")
    kinds = [type(s).__name__ for s in prog.body]
    assert kinds == ["Allocate", "Copy", "Copy", "ComputeGEMM", "ComputeOp",
                     "Reshape", "ComputeGEMM", "ForLoop"]
    gemm = prog.body[3]
    assert gemm.a.name == "Q_shared" and not gemm.a.transposed
    assert gemm.b.name == "K_shared" and gemm.b.transposed
    assert prog.body[6].accumulate
    loop = prog.body[7]
    assert loop.var == "i" and loop.body[0].coords == {"L": "i+1"}


def test_parse_rejects_garbage():
    with pytest.raises(TLSyntaxError):
        parse("Frobnicate Q into the warp scheduler")


def test_unbalanced_blocks_rejected():
    with pytest.raises(TLSyntaxError):
        parse("for i = 0:4\nCompute Softmax S")
    with pytest.raises(TLSyntaxError):
        parse("end")


_names = st.sampled_from(["Q", "K", "V", "S", "P", "acc", "m", "l", "O"])
_dims = st.sampled_from(["BM", "BN", "HeadDim", "M", "N", 128, 64])
_spaces = st.sampled_from(list(MemSpace))


@st.composite
def _statements(draw, depth=0):
    kind = draw(st.integers(0, 5 if depth < 2 else 4))
    if kind == 0:
        return Allocate(draw(_names), draw(_spaces),
                        tuple(draw(st.lists(_dims, min_size=1, max_size=3))),
                        dtype=draw(st.sampled_from(["bf16", "f32"])),
                        offset=draw(st.sampled_from([None, "bh", "b"])))
    if kind == 1:
        src, dst = draw(_spaces), draw(_spaces)
        shape = tuple(draw(st.lists(_dims, min_size=2, max_size=2)))
        coords = draw(st.sampled_from([None, {"L": "i"}, {"L": "q"}]))
        return Copy(draw(_names), src, dst, shape, coords)
    if kind == 2:
        return ComputeGEMM(
            TensorRef(draw(_names), draw(st.booleans())),
            TensorRef(draw(_names), draw(st.booleans())),
            draw(_names), draw(st.booleans()))
    if kind == 3:
        return ComputeOp(
            draw(st.sampled_from(["softmax", "scale", "divide", "cast",
                                  "online_softmax"])),
            tuple(draw(st.lists(_names, min_size=1, max_size=3))),
            out=draw(st.one_of(st.none(), _names)))
    if kind == 4:
        return Reshape(draw(_names), "mma_C", "mma_A")
    body = draw(st.lists(_statements(depth=depth + 1), min_size=1,
                         max_size=3))
    return ForLoop("i", 0, draw(st.sampled_from(["Tkv", 4])), body)


@given(st.lists(_statements(), min_size=1, max_size=8))
@settings(max_examples=80, deadline=None)
def test_print_parse_roundtrip(stmts):
    prog = TLProgram("prop", stmts)
    text = to_text(prog)
    re_parsed = parse(text, name="prop")
    assert to_text(re_parsed) == text  # canonical fixed point


def test_roundtrip_preserves_semantics_fields():
    prog = TLProgram("x", [
        Copy("K", MemSpace.GLOBAL, MemSpace.SHARED, ("BN", "HeadDim"),
             {"L": "i"}),
        ComputeGEMM(TensorRef("Q"), TensorRef("K", True), "S"),
    ])
    rt = parse(to_text(prog))
    assert rt.body[0].coords == {"L": "i"}
    assert rt.body[1].b.transposed
