"""Chunked-prefill kernels: one prompt chunk attending causally, through a
block table, to the pages already written (history + the chunk itself).

The contract under test (this PR's tentpole): a ``chunk_prefill`` TL
program takes the per-row *history length* as its runtime scalar — the
causal diagonal is shifted by it at run time — so one compiled kernel
serves every chunk position within a (chunk capacity, bucket) pair, and
the result equals dense causal attention over the logical cache the table
encodes, for every head geometry, dtype, page placement, and chunk size
(including chunks that do not divide the prompt or the page size).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.core.pipeline import cached_kernel
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}

_DT = {"bfloat16": "bf16", "float32": "f32"}


def _paged_case(rng, *, b, hkv, d, ps, tp, pool_pages, dtype):
    """Random pool + per-row permuted block tables + the dense view."""
    kp = jnp.asarray(rng.standard_normal((pool_pages, hkv, ps, d)) * 0.5,
                     dtype)
    vp = jnp.asarray(rng.standard_normal((pool_pages, hkv, ps, d)) * 0.5,
                     dtype)
    perm = rng.permutation(pool_pages)[: b * tp]
    tables = np.asarray(perm, np.int32).reshape(b, tp)
    kd = jnp.stack([jnp.concatenate([kp[t] for t in row], axis=1)
                    for row in tables])
    vd = jnp.stack([jnp.concatenate([vp[t] for t in row], axis=1)
                    for row in tables])
    return kp, vp, tables, kd, vd


def _check_rows(out, q, kd, vd, hist, c, tol):
    """Row b of the chunk == dense causal attention over cache[:hist_b+c]
    (bottom-right aligned: chunk row i sits at position hist_b + i)."""
    for bi in range(len(hist)):
        n = int(hist[bi]) + c
        gold = ref.attention(q[bi:bi + 1].astype(jnp.float32),
                             kd[bi:bi + 1, :, :n].astype(jnp.float32),
                             vd[bi:bi + 1, :, :n].astype(jnp.float32),
                             causal=True)
        np.testing.assert_allclose(
            np.asarray(out[bi:bi + 1], np.float32), np.asarray(gold),
            atol=tol, rtol=tol, err_msg=f"row {bi} hist={hist[bi]}")


@pytest.mark.parametrize("seed", range(8))
def test_chunk_prefill_matches_dense_causal(seed):
    """Paged chunk prefill == dense causal reference for random geometry,
    page size, chunk length (ragged), per-row history, and dtype."""
    rng = np.random.default_rng(seed)
    hq, hkv = [(4, 4), (8, 2), (4, 1), (6, 3)][seed % 4]   # MHA/GQA/MQA
    d = int(rng.choice([32, 64]))
    ps = int(rng.choice([16, 32]))
    tp = int(rng.choice([2, 4]))
    dtype = [jnp.float32, jnp.float32, jnp.bfloat16][seed % 3]
    b = 2
    bucket = ps * tp
    c = int(rng.integers(1, ps + ps // 2))     # often not a page multiple
    hist = np.asarray([int(rng.integers(0, bucket - c + 1))
                       for _ in range(b)], np.int32)
    kp, vp, tables, kd, vd = _paged_case(
        rng, b=b, hkv=hkv, d=d, ps=ps, tp=tp, pool_pages=b * tp + 3,
        dtype=dtype)
    q = jnp.asarray(rng.standard_normal((b, hq, c, d)) * 0.5, dtype)

    out = ops.paged_flash_prefill(q, kp, vp, tables, hist_len=hist)
    _check_rows(out, q, kd, vd, hist, c, TOL[dtype])


def test_chunk_prefill_pallas_vs_jnp_oracle():
    """Backend agreement on the same chunk-prefill TL program: the Pallas
    kernel's runtime-shifted causal gather and the jnp oracle's must be
    the same function."""
    rng = np.random.default_rng(77)
    hq, hkv, d, ps, tp, c = 4, 2, 32, 16, 4, 24
    bucket = ps * tp
    b = 2
    kp, vp, tables, _, _ = _paged_case(
        rng, b=b, hkv=hkv, d=d, ps=ps, tp=tp, pool_pages=b * tp + 2,
        dtype=jnp.float32)
    hist = np.asarray([5, 33], np.int32)
    spec = AttnSpec(variant="gqa", num_q_heads=hq, num_kv_heads=hkv,
                    head_dim=d, causal=True, mode="chunk_prefill",
                    dtype="f32", page_size=ps)
    kern = cached_kernel(spec, c, bucket, "v5e", True, True)
    assert kern.pallas_fn.chunk_prefill and kern.oracle_fn.chunk_prefill
    assert kern.pallas_fn.paged and kern.oracle_fn.paged
    q = jnp.asarray(rng.standard_normal((b, hq, c, d)) * 0.5, jnp.float32)
    qp = ops._pad_rows(q, 2, kern.blocks.bm)
    out = kern.pallas_fn(jnp.asarray(hist), jnp.asarray(tables), qp, kp, vp)
    g = hq // hkv
    for bi in range(b):
        for h in range(hq):
            o = kern.oracle_fn(int(hist[bi]), tables[bi], qp[bi, h],
                               kp[:, h // g].reshape(-1, d),
                               vp[:, h // g].reshape(-1, d))[:c]
            np.testing.assert_allclose(
                np.asarray(out[bi, h, :c], np.float32), np.asarray(o),
                atol=1e-5, rtol=1e-5, err_msg=f"row {bi} head {h}")


@pytest.mark.parametrize("seed", range(5))
def test_mla_chunk_prefill_matches_dense(seed):
    rng = np.random.default_rng(300 + seed)
    h = int(rng.choice([4, 8]))
    r, rr = int(rng.choice([32, 64])), 16
    ps, tp = 16, 4
    bucket = ps * tp
    dtype = jnp.float32 if seed % 2 else jnp.bfloat16
    b = 2
    c = int(rng.integers(1, ps + ps // 2))
    hist = np.asarray([int(rng.integers(0, bucket - c + 1))
                       for _ in range(b)], np.int32)
    pool_pages = b * tp + 2
    cp = jnp.asarray(rng.standard_normal((pool_pages, ps, r + rr)) * 0.3,
                     dtype)
    tables = np.asarray(rng.permutation(pool_pages)[: b * tp],
                        np.int32).reshape(b, tp)
    ql = jnp.asarray(rng.standard_normal((b, h, c, r + rr)) * 0.3, dtype)

    out = ops.paged_mla_prefill(ql, cp, tables, hist_len=hist,
                                kv_lora_rank=r, rope_head_dim=rr)
    cd = jnp.stack([jnp.concatenate([cp[t] for t in row], axis=0)
                    for row in tables])
    for bi in range(b):
        n = int(hist[bi]) + c
        gold = ref.mla_attention(ql[bi:bi + 1].astype(jnp.float32),
                                 cd[bi:bi + 1, :n].astype(jnp.float32),
                                 rope_dim=rr, scale=(128 + rr) ** -0.5,
                                 causal=True)
        np.testing.assert_allclose(
            np.asarray(out[bi:bi + 1], np.float32), np.asarray(gold),
            atol=TOL[dtype], rtol=TOL[dtype],
            err_msg=f"row {bi} hist={hist[bi]}")


@pytest.mark.parametrize("seed", range(4))
def test_chunk_prefill_int8_parity(seed):
    """Int8 pools + per-page scales: a chunk attending through the table
    to quantized history stays within the documented bound of the fp-pool
    result, including the write path (``paged_scatter_chunk_quant`` fills
    the chunk's own pages before the kernel reads them back)."""
    from repro.models.attention import (paged_scatter_chunk,
                                        paged_scatter_chunk_quant)
    rng = np.random.default_rng(600 + seed)
    hq, hkv = [(4, 4), (8, 2), (4, 1), (6, 3)][seed % 4]
    d, ps, tp, b = 32, 16, 4, 2
    bucket = ps * tp
    c = ps          # chunk == one page: scatter fills whole pages
    hist = np.asarray([0, ps], np.int32)
    pool_pages = b * tp + 2
    # fp pools hold the history; the int8 pools hold the same values
    # quantized through the production write path
    kp, vp, tables, _, _ = _paged_case(
        rng, b=b, hkv=hkv, d=d, ps=ps, tp=tp, pool_pages=pool_pages,
        dtype=jnp.float32)
    ki = jnp.zeros(kp.shape, jnp.int8)
    vi = jnp.zeros(vp.shape, jnp.int8)
    ks = jnp.zeros((pool_pages,), jnp.float32)
    vs = jnp.zeros((pool_pages,), jnp.float32)
    # replay the pool contents page by page through the quantized scatter
    # (start = page boundary, chunk = full page) so scales grow exactly as
    # the engine would have grown them
    for pi in range(tp):
        newk = jnp.stack([kp[tables[bi, pi]] for bi in range(b)])
        newv = jnp.stack([vp[tables[bi, pi]] for bi in range(b)])
        start = jnp.full((b,), pi * ps, jnp.int32)
        ki, ks = paged_scatter_chunk_quant(ki, tables, start, newk,
                                           scale=ks)
        vi, vs = paged_scatter_chunk_quant(vi, tables, start, newv,
                                           scale=vs)
    q = jnp.asarray(rng.standard_normal((b, hq, c, d)) * 0.5, jnp.float32)
    fp = ops.paged_flash_prefill(q, kp, vp, tables, hist_len=hist)
    qout = ops.paged_flash_prefill(q, ki, vi, tables, hist_len=hist,
                                   kv_scales=(ks, vs))
    np.testing.assert_allclose(np.asarray(qout), np.asarray(fp), atol=5e-2,
                               rtol=0, err_msg=f"Hq={hq} Hkv={hkv}")
    # and the quantized scatter wrote where the fp scatter would have
    kref = paged_scatter_chunk(jnp.zeros(kp.shape, jnp.float32), tables,
                               jnp.zeros((b,), jnp.int32),
                               jnp.stack([kp[tables[bi, 0]]
                                          for bi in range(b)]))
    deq = np.asarray(ki, np.float32) * np.asarray(ks)[:, None, None, None]
    for bi in range(b):
        p0 = tables[bi, 0]
        np.testing.assert_allclose(deq[p0], np.asarray(kref[p0]), atol=5e-2,
                                   rtol=0, err_msg=f"row {bi} page write")


def test_mla_chunk_prefill_int8_parity():
    """MLA latent pages quantize with one scale vector through the chunk
    path too."""
    rng = np.random.default_rng(71)
    b, h, r, rr, ps, tp = 2, 4, 64, 16, 16, 4
    bucket = ps * tp
    c = 12
    hist = np.asarray([0, 20], np.int32)
    pool_pages = b * tp + 2
    cp = jnp.asarray(rng.standard_normal((pool_pages, ps, r + rr)) * 0.3,
                     jnp.float32)
    tables = np.asarray(rng.permutation(pool_pages)[: b * tp],
                        np.int32).reshape(b, tp)
    flat = np.asarray(cp, np.float32).reshape(pool_pages, -1)
    cs = np.abs(flat).max(axis=1) / 127.0
    ci = jnp.asarray(np.clip(np.round(
        flat / np.maximum(cs, 1e-30)[:, None]), -127, 127
    ).astype(np.int8).reshape(cp.shape))
    ql = jnp.asarray(rng.standard_normal((b, h, c, r + rr)) * 0.3,
                     jnp.float32)
    fp = ops.paged_mla_prefill(ql, cp, tables, hist_len=hist,
                               kv_lora_rank=r, rope_head_dim=rr)
    qout = ops.paged_mla_prefill(ql, ci, tables, hist_len=hist,
                                 c_scale=jnp.asarray(cs, jnp.float32),
                                 kv_lora_rank=r, rope_head_dim=rr)
    np.testing.assert_allclose(np.asarray(qout), np.asarray(fp), atol=5e-2,
                               rtol=0)


def test_one_kernel_per_chunk_shape():
    """Every (history, table placement) within one (chunk capacity,
    bucket) pair reuses one generated kernel — the history length and the
    block table are runtime data."""
    rng = np.random.default_rng(9)
    hq, hkv, d, ps, tp, c = 4, 2, 32, 16, 2, 16
    kp = jnp.asarray(rng.standard_normal((6, hkv, ps, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((6, hkv, ps, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, hq, c, d)), jnp.float32)
    ops.paged_flash_prefill(q, kp, vp, np.asarray([[0, 1]], np.int32),
                            hist_len=0)           # warm the shape
    before = cached_kernel.cache_info()
    for hist in range(0, ps + 1, 3):
        tbl = np.asarray([rng.permutation(6)[:tp]], np.int32)
        ops.paged_flash_prefill(q, kp, vp, tbl, hist_len=hist)
    after = cached_kernel.cache_info()
    assert after.misses == before.misses, (
        "chunk prefill retraced the TL pipeline for runtime data "
        "(history length / block table) inside one compiled shape")
    assert after.hits > before.hits


def test_spec_validation():
    with pytest.raises(ValueError, match="page_size"):
        AttnSpec.mha(4, 32, mode="chunk_prefill")       # paged-only mode
    with pytest.raises(ValueError, match="causal"):
        AttnSpec.mha(4, 32, mode="chunk_prefill", causal=False,
                     page_size=16)
    with pytest.raises(ValueError, match="window"):
        AttnSpec.mha(4, 32, mode="chunk_prefill", page_size=16, window=8)


@given(
    ps=st.sampled_from([16, 32]),
    tp=st.sampled_from([2, 4]),
    cfrac=st.floats(0.05, 1.5),
    hfrac=st.floats(0.0, 1.0),
    geom=st.sampled_from([(4, 4), (8, 2), (4, 1), (6, 3)]),
    use_bf16=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
@settings(max_examples=15, deadline=None)
def test_chunk_prefill_property(ps, tp, cfrac, hfrac, geom, use_bf16, seed):
    """For any page geometry, chunk fraction (including ragged chunks),
    history fraction, head geometry and dtype: chunked == dense causal on
    the logical cache the table encodes."""
    rng = np.random.default_rng(seed)
    hq, hkv = geom
    d = 32
    dtype = jnp.bfloat16 if use_bf16 else jnp.float32
    bucket = ps * tp
    c = max(1, min(bucket, int(round(cfrac * ps))))
    hist = np.asarray([int(round(hfrac * (bucket - c)))], np.int32)
    kp, vp, tables, kd, vd = _paged_case(
        rng, b=1, hkv=hkv, d=d, ps=ps, tp=tp, pool_pages=tp + 2,
        dtype=dtype)
    q = jnp.asarray(rng.standard_normal((1, hq, c, d)) * 0.5, dtype)
    out = ops.paged_flash_prefill(q, kp, vp, tables, hist_len=hist)
    _check_rows(out, q, kd, vd, hist, c, TOL[dtype])
