"""Prefix-sharing serving: cached pages mapped into new requests' block
tables, copy-on-write at the divergence point, chunked prefill directly
into pages — all invisible to the tokens.

Every test's ground truth is a cold engine (or a solo dense run): prefix
reuse, COW, preemption of shared holders, and cache hits after the
original request retired must change *which pages hold the KV*, never
what any request generates.
"""

import jax
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


def _mk(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 128)
    kw.setdefault("page_size", 16)
    return ServeEngine(cfg, params, **kw)


def _drain(engine, reqs, n=6):
    uids = [engine.submit(p, max_new_tokens=n) for p in reqs]
    done = engine.run_until_drained()
    by_uid = {r.uid: list(r.tokens) for r in done}
    return [by_uid[u] for u in uids]


@pytest.fixture(scope="module")
def gqa():
    cfg = registry.get_reduced("deepseek-7b")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def _prompts_with_shared_prefix(cfg, rng, *, prefix_len, tails):
    pre = list(map(int, rng.integers(0, cfg.vocab_size, prefix_len)))
    return [pre + list(map(int, rng.integers(0, cfg.vocab_size, t)))
            for t in tails]


# --------------------------------------------------------------------------
# parity: shared-prefix serving == cold-start serving
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deepseek-7b", "deepseek-v2-lite-16b",
                                  "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b"])
def test_paged_chunked_engine_matches_cold_solo(arch):
    """Chunked-into-pages prefill (+ prefix cache where it is sound) must
    reproduce dense solo tokens on GQA, MLA + first_k_dense, MoE (single
    exact chunk, prefix cache off) and hybrid recurrent architectures,
    with ragged prompt lengths that do not divide the chunk size."""
    cfg = registry.get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    prompts = _prompts_with_shared_prefix(cfg, rng, prefix_len=18,
                                          tails=[1, 9, 23])
    engine = _mk(cfg, params, max_batch=3, prefill_chunk=32)
    assert engine.paged
    got = _drain(engine, prompts, n=5)
    for i, p in enumerate(prompts):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=128,
                           paged=False)
        ref = solo.generate([p], max_new_tokens=5).tokens[0]
        np.testing.assert_array_equal(np.asarray(got[i]), ref,
                                      err_msg=f"{arch} request {i}")
    # drained: every page is reclaimable (live = dump page only)
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_shared_prefix_decode_equals_cold_start(gqa):
    """Satellite: logits downstream of a prefix-cache hit are the cold
    path's logits — greedy tokens must be identical with the cache on and
    off, and the hit must actually happen."""
    cfg, params = gqa
    rng = np.random.default_rng(21)
    prompts = _prompts_with_shared_prefix(cfg, rng, prefix_len=40,
                                          tails=[3, 7])
    warm = _mk(cfg, params)
    cold = _mk(cfg, params, prefix_cache=False)
    warm_toks = _drain(warm, prompts)
    cold_toks = _drain(cold, prompts)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(np.asarray(warm_toks[i]),
                                      np.asarray(cold_toks[i]),
                                      err_msg=f"request {i}")
    assert warm.prefix_hit_tokens > 0, "the shared prefix never hit"
    assert cold.prefix_hit_tokens == 0
    # reuse really skipped compute: fewer prompt tokens were prefilled
    assert warm.prefill_tokens < cold.prefill_tokens


# --------------------------------------------------------------------------
# copy-on-write at the divergence point
# --------------------------------------------------------------------------

def test_cow_fires_exactly_once_on_divergence(gqa):
    """Two live requests sharing a prefix that diverges mid-page: the
    second request COWs the partial page exactly once, both keep their
    solo tokens, and compile counters stay bounded by shapes."""
    cfg, params = gqa
    rng = np.random.default_rng(22)
    # the 35-token shared prefix ends mid-page-2; A's 13-token tail fills
    # that page (48 = 3 full pages, so page 2 is registered and matchable)
    # while B diverges 3 tokens into it — the COW trigger geometry
    pa, pb = _prompts_with_shared_prefix(cfg, rng, prefix_len=35,
                                         tails=[13, 5])
    engine = _mk(cfg, params)
    ua = engine.submit(pa, max_new_tokens=6)
    engine.step()                       # A admitted, pages registered
    assert engine.cow_count == 0
    ub = engine.submit(pb, max_new_tokens=6)
    done = engine.run_until_drained()
    by_uid = {r.uid: list(r.tokens) for r in done}
    assert engine.cow_count == 1, (
        f"divergence through one shared partial page must COW exactly "
        f"once, saw {engine.cow_count}")
    assert engine.prefix_hit_tokens >= 32, "B should reuse A's full pages"
    for uid, p in ((ua, pa), (ub, pb)):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=128,
                           paged=False)
        np.testing.assert_array_equal(
            np.asarray(by_uid[uid]),
            solo.generate([p], max_new_tokens=6).tokens[0],
            err_msg=f"request {uid}")
    # decode compiled per bucket, chunk prefill per (cap, bucket) shape
    assert engine.decode_compiles <= 2
    assert engine.prefill_compiles <= 3
    assert engine.allocator.free_pages == engine.num_pages - 1


def test_page_aligned_shared_prefix_needs_no_cow(gqa):
    """Divergence exactly at a page boundary shares whole pages without
    ever writing them — no COW, no extra pages for the shared span."""
    cfg, params = gqa
    rng = np.random.default_rng(23)
    pa, pb = _prompts_with_shared_prefix(cfg, rng, prefix_len=32,
                                         tails=[6, 9])
    engine = _mk(cfg, params)
    engine.submit(pa, max_new_tokens=4)
    engine.step()
    before = engine.allocator.alloc_count
    engine.submit(pb, max_new_tokens=4)
    engine.run_until_drained()
    assert engine.cow_count == 0
    assert engine.prefix_hit_tokens >= 32
    # B allocated pages only for its tail + decode growth, not the prefix
    assert engine.allocator.alloc_count - before <= 3


# --------------------------------------------------------------------------
# lifetime edge cases
# --------------------------------------------------------------------------

def test_preempting_shared_holder_leaves_survivor_intact(gqa):
    """Preemption of a request holding shared pages only drops *its*
    references — the survivor's cache (including the shared pages) stays
    valid and its tokens match solo generation."""
    cfg, params = gqa
    rng = np.random.default_rng(24)
    # one 16-token page shared; tiny pool forces mid-decode preemption
    pa, pb = _prompts_with_shared_prefix(cfg, rng, prefix_len=16,
                                         tails=[2, 3])
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64,
                         page_size=16, num_pages=5)
    ua = engine.submit(pa, max_new_tokens=20)
    ub = engine.submit(pb, max_new_tokens=20)
    done = engine.run_until_drained(max_steps=400)
    by_uid = {r.uid: list(r.tokens) for r in done}
    for uid, p in ((ua, pa), (ub, pb)):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=64,
                           paged=False)
        np.testing.assert_array_equal(
            np.asarray(by_uid[uid]),
            solo.generate([p], max_new_tokens=20).tokens[0],
            err_msg=f"request {uid}")
    assert engine.allocator.free_pages == engine.num_pages - 1
    engine.allocator.check_invariants()


def test_prefix_hit_after_original_retires(gqa):
    """Retired requests' full pages stay matchable (evictable cache):  a
    later identical-prefix request hits them with zero live sharers, and
    still generates exactly the cold tokens."""
    cfg, params = gqa
    rng = np.random.default_rng(25)
    p1, p2 = _prompts_with_shared_prefix(cfg, rng, prefix_len=33,
                                         tails=[2, 4])
    engine = _mk(cfg, params)
    t1 = _drain(engine, [p1])[0]
    assert not engine.active_requests
    hits_before = engine.prefix_hit_tokens
    t2 = _drain(engine, [p2])[0]
    assert engine.prefix_hit_tokens - hits_before >= 32, (
        "wave-2 prompt must hit the retired request's cached pages")
    solo = ServeEngine(cfg, params, max_batch=1, max_len=128, paged=False)
    np.testing.assert_array_equal(
        np.asarray(t2), solo.generate([p2], max_new_tokens=6).tokens[0])
    del t1
    engine.allocator.check_invariants()


def test_cache_eviction_under_pressure_keeps_serving(gqa):
    """A pool sized so cached pages must be evicted to admit new work:
    eviction reclaims LRU cache pages transparently and every request
    still matches its solo tokens."""
    cfg, params = gqa
    rng = np.random.default_rng(26)
    waves = [_prompts_with_shared_prefix(cfg, rng, prefix_len=16,
                                         tails=[3])[0]
             for _ in range(4)]                        # 4 distinct prefixes
    engine = ServeEngine(cfg, params, max_batch=1, max_len=64,
                         page_size=16, num_pages=4)    # 3 allocatable
    outs = [_drain(engine, [p], n=4)[0] for p in waves]
    assert engine.allocator.evictions > 0, "pool never felt the cache"
    for p, got in zip(waves, outs):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=64,
                           paged=False)
        np.testing.assert_array_equal(
            np.asarray(got), solo.generate([p], max_new_tokens=4).tokens[0])
    engine.allocator.check_invariants()


def test_run_until_drained_exception_carries_finished_and_reclaims(gqa):
    """Satellite regression: exhausting max_steps raises with the already-
    finished requests riding on ``err.finished``, the un-finished request
    resumes on the next call, and afterwards the allocator is fully
    reclaimed (shared pages included)."""
    cfg, params = gqa
    rng = np.random.default_rng(27)
    short, long = _prompts_with_shared_prefix(cfg, rng, prefix_len=20,
                                              tails=[1, 2])
    engine = _mk(cfg, params)
    u_short = engine.submit(short, max_new_tokens=2)
    u_long = engine.submit(long, max_new_tokens=40)
    with pytest.raises(RuntimeError, match="still pending") as ei:
        engine.run_until_drained(max_steps=5)
    finished = ei.value.finished
    assert [r.uid for r in finished] == [u_short], (
        "finished results must ride on the exception")
    assert len(finished[0].tokens) == 2
    # the long request is still live with its pages intact — resume
    assert [r.uid for r in engine.active_requests] == [u_long]
    assert engine.allocator.live_pages > 1      # dump + the live request
    done = engine.run_until_drained()
    assert [r.uid for r in done] == [u_long]
    assert len(done[0].tokens) == 40
    # full reclamation: only the dump page stays live
    assert engine.allocator.live_pages == 1
    assert engine.allocator.free_pages == engine.num_pages - 1
    engine.allocator.check_invariants()


def test_long_prompt_after_partial_page_hit(gqa):
    """Regression: a partial-page prefix hit leaves the suffix prefill
    starting mid-page; the boundary-snapping chunk must re-align to the
    page grid without the tail chunk's padding ever crossing max_len
    (this used to raise 'cache length ... exceeds max_len' mid-serve when
    the follower's prompt approached max_len)."""
    cfg, params = gqa
    rng = np.random.default_rng(28)
    pre = list(map(int, rng.integers(0, cfg.vocab_size, 33)))
    pa = pre + list(map(int, rng.integers(0, cfg.vocab_size, 15)))  # 48
    pb = pre + list(map(int, rng.integers(0, cfg.vocab_size, 94)))  # 127
    engine = _mk(cfg, params, max_batch=1)     # max_len=128, page 16
    ta = _drain(engine, [pa], n=2)[0]
    tb = _drain(engine, [pb], n=1)[0]          # used to raise here
    assert engine.prefix_hit_tokens >= 33, "partial-page hit expected"
    for p, got, n in ((pa, ta, 2), (pb, tb, 1)):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=128,
                           paged=False)
        np.testing.assert_array_equal(
            np.asarray(got), solo.generate([p], max_new_tokens=n).tokens[0])
    engine.allocator.check_invariants()


@pytest.mark.parametrize("seed", range(3))
def test_random_shared_prefix_workload_stays_exact(gqa, seed):
    """Engine-level interleaving property: random waves of prefix-sharing
    prompts through a deliberately tight pool (forcing queueing,
    preemption, COW and eviction together) still produce every request's
    solo tokens, and the allocator conserves pages throughout."""
    cfg, params = gqa
    rng = np.random.default_rng(100 + seed)
    pre = list(map(int, rng.integers(0, cfg.vocab_size, 24)))
    prompts = []
    for _ in range(5):
        tail = list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(1, 12)))))
        cut = int(rng.integers(8, 25))     # varying shared-prefix depth
        prompts.append(pre[:cut] + tail)
    engine = ServeEngine(cfg, params, max_batch=2, max_len=64,
                         page_size=16, num_pages=7)
    uids = [engine.submit(p, max_new_tokens=int(rng.integers(2, 8)))
            for p in prompts]
    budgets = {u: engine._queue[i].max_new_tokens
               for i, u in enumerate(uids)}
    done = engine.run_until_drained(max_steps=500)
    engine.allocator.check_invariants()
    assert engine.allocator.free_pages == engine.num_pages - 1
    by_uid = {r.uid: list(r.tokens) for r in done}
    for u, p in zip(uids, prompts):
        solo = ServeEngine(cfg, params, max_batch=1, max_len=64,
                           paged=False)
        ref = solo.generate([p], max_new_tokens=budgets[u]).tokens[0]
        np.testing.assert_array_equal(np.asarray(by_uid[u]), ref,
                                      err_msg=f"request {u} (seed {seed})")


def test_prefix_cache_off_for_unsound_archs():
    """Recurrent state and capacity-truncated MoE make prefix reuse
    numerics-changing — the engine must refuse to enable it there."""
    for arch in ("jamba-1.5-large-398b", "qwen3-moe-235b-a22b"):
        cfg = registry.get_reduced(arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        engine = ServeEngine(cfg, params, max_batch=1, max_len=64,
                             page_size=16, prefix_cache=True)
        assert engine.paged and not engine.prefix_cache


# --------------------------------------------------------------------------
# satellite: mlen = min(mlen, plen - 1) truncation at page-boundary prompts
# --------------------------------------------------------------------------

def test_full_match_page_aligned_prompt_cows_shared_final_page(gqa):
    """plen ≡ 0 (mod page_size) with a *fully* cached prompt: the
    truncation to plen - 1 re-enters the final shared page mid-page, so
    the one recomputed token would be written into a page another live
    request still reads.  COW must make the writer's copy private — the
    original holder's decode stream is the corruption oracle."""
    cfg, params = gqa
    rng = np.random.default_rng(41)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 32)))  # 2 pages
    engine = _mk(cfg, params)
    ua = engine.submit(list(prompt), max_new_tokens=12)
    engine.step()                       # A live, both full pages registered
    assert engine.cow_count == 0
    ub = engine.submit(list(prompt), max_new_tokens=12)
    done = engine.run_until_drained()
    assert engine.cow_count == 1, (
        f"the fully-matched shared final page must COW exactly once "
        f"before B recomputes token 31 into it, saw {engine.cow_count}")
    assert engine.prefix_hit_tokens == 31   # plen - 1, not plen
    by_uid = {r.uid: list(r.tokens) for r in done}
    solo = _solo_tokens_list(cfg, params, prompt, 12)
    np.testing.assert_array_equal(np.asarray(by_uid[ua]), solo,
                                  err_msg="holder A was corrupted")
    np.testing.assert_array_equal(np.asarray(by_uid[ub]), solo,
                                  err_msg="writer B diverged")
    assert engine.allocator.free_pages == engine.num_pages - 1
    engine.allocator.check_invariants()


def test_full_match_one_past_boundary_needs_no_cow(gqa):
    """plen ≡ 1 (mod page_size): truncation to plen - 1 lands exactly on
    a page boundary, the shared pages are only ever read, and the one
    recomputed token opens the writer's own fresh page — zero COWs."""
    cfg, params = gqa
    rng = np.random.default_rng(42)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 33)))
    engine = _mk(cfg, params)
    ua = engine.submit(list(prompt), max_new_tokens=12)
    engine.step()                       # A live: pages 0,1 registered
    ub = engine.submit(list(prompt), max_new_tokens=12)
    done = engine.run_until_drained()
    assert engine.cow_count == 0, (
        "a page-aligned truncated match shares read-only pages; "
        f"saw {engine.cow_count} COWs")
    assert engine.prefix_hit_tokens == 32   # the two full pages
    by_uid = {r.uid: list(r.tokens) for r in done}
    solo = _solo_tokens_list(cfg, params, prompt, 12)
    np.testing.assert_array_equal(np.asarray(by_uid[ua]), solo)
    np.testing.assert_array_equal(np.asarray(by_uid[ub]), solo)
    assert engine.allocator.free_pages == engine.num_pages - 1
    engine.allocator.check_invariants()


def _solo_tokens_list(cfg, params, prompt, n):
    solo = ServeEngine(cfg, params, max_batch=1, max_len=128, paged=False)
    return solo.generate([prompt], max_new_tokens=n).tokens[0]
