"""Paper Table 9 (NSA) analogue: sparse/windowed attention generality.

The paper applies its pipeline to NSA (native sparse attention) and beats
the naive implementation ~1.25x.  The TL pipeline here expresses the
sliding-window family the same way — one extra TL mask statement in the
sketch — so this benchmark compares full-causal vs windowed TL kernels
(both generated, same workflow) against the naive reference, plus the
autotuner's projected win from the skipped KV blocks.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import autotune
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref
from .common import CsvOut, timeit


def run(full: bool = False):
    seqlens = [512, 1024, 2048, 4096, 8192, 16384] if full else [512, 1024, 2048]
    heads, d, w = 16, 128, 256
    out = CsvOut(["seqlen", "window", "naive_ms", "tl_full_ms", "tl_win_ms",
                  "est_full_tflops", "est_win_tflops"])
    rng = np.random.default_rng(0)
    for s in seqlens:
        b = max(1, 2048 // s)
        q = jnp.asarray(rng.standard_normal((b, heads, s, d)) * 0.5,
                        jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, heads, s, d)) * 0.5,
                        jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, heads, s, d)) * 0.5,
                        jnp.float32)
        t_naive = timeit(lambda: ref.attention(q, k, v, causal=True,
                                               window=w))
        t_full = timeit(lambda: ops.flash_attention(q, k, v, causal=True))
        t_win = timeit(lambda: ops.flash_attention(q, k, v, causal=True,
                                                   window=w))
        e_full = autotune.tune(AttnSpec.mha(heads, d), s, s, "v5e")
        e_win = autotune.tune(AttnSpec.mha(heads, d, window=w), s, s, "v5e")
        out.row(s, w, f"{t_naive*1e3:.1f}", f"{t_full*1e3:.1f}",
                f"{t_win*1e3:.1f}", f"{e_full.efficiency*197:.1f}",
                f"{e_win.efficiency*197:.1f}")


if __name__ == "__main__":
    run()
