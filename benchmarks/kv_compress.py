"""Int8-quantized KV pages A/B: serving capacity on a fixed HBM budget.

The quantized-page contract (this PR's tentpole) stores every KV page
pool as symmetric int8 with one f32 absmax scale per page, dequantized
per page inside the kernel's KV loop — Q/O/compute dtypes unchanged, a
bounded dequant error (see README), and a 2x (bf16) / ~4x (f32) smaller
cache row per token.

This benchmark makes the capacity claim concrete the way an operator
would provision it: fix one KV HBM byte budget, size each engine's page
pool to that budget at *its* bytes-per-page (fp pools pay the model
dtype; int8 pools pay 1 byte/element + 4 bytes/page/scale), then drive
both engines over the same oversubscribed request wave and report

* KV HBM reserved per request at its peak length,
* the peak number of *concurrently resident* requests the pool sustains
  (the capacity headline — target >= 1.8x for the quantized engine), and
* steady-state decode tok/s (the dequant is a per-page multiply riding
  the existing gather; it must not move throughput materially), with the
  compile counters asserted identical — quantization changes the cache
  dtype, never the compile-key space.

Results land in ``BENCH_kvq.json``.

    PYTHONPATH=src python benchmarks/kv_compress.py --arch deepseek-7b
    PYTHONPATH=src python benchmarks/kv_compress.py --tiny   # CI smoke
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine

from paged_kv import drive, kv_bytes_per_token


def page_bytes(cfg, page_size: int, quant: bool) -> int:
    """HBM bytes one page pool slot occupies across all attention layers.

    fp pages pay the model dtype per element; int8 pages pay one byte per
    element plus one f32 absmax scale per (page, pool) — two pools (K, V)
    for MHA-family caches, one latent pool for MLA."""
    kinds, nper = T.period_spec(cfg)
    if cfg.mla:
        elems = cfg.kv_lora_rank + cfg.rope_head_dim
        n_scales = 1
    else:
        elems = 2 * cfg.num_kv_heads * cfg.head_dim
        n_scales = 2
    if quant:
        row = elems * page_size * 1 + n_scales * 4
    else:
        bytes_per = 2 if cfg.dtype in ("bf16", "f16") else 4
        row = elems * page_size * bytes_per
    n_attn = sum(k in ("attn", "self") for k in kinds) * nper
    n_attn += cfg.first_k_dense if not getattr(cfg, "rwkv", False) else 0
    return row * n_attn


def peak_concurrency(eng: ServeEngine, prompts, new_tokens) -> int:
    """Submit everything, step to drain, return the peak number of
    requests concurrently holding pages — the pool's capacity under the
    scheduler's own admission/preemption policy, not a closed form."""
    for p in prompts:
        eng.submit(list(p), max_new_tokens=new_tokens)
    peak, steps = 0, 0
    while (eng._queue or any(r is not None for r in eng._active)) \
            and steps < 20000:
        eng.step()
        peak = max(peak, sum(r is not None for r in eng._active))
        steps += 1
    assert not eng._queue, "wave did not drain"
    return peak


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=100)
    ap.add_argument("--fp-pages", type=int, default=24,
                    help="fp pool size; sets the shared HBM byte budget")
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale smoke run for CI")
    args = ap.parse_args()
    if args.tiny:
        args.page_size, args.new_tokens = 16, 3
        args.prompt_len, args.fp_pages = 24, 8

    cfg = registry.get_reduced(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ps = args.page_size

    pb_fp = page_bytes(cfg, ps, quant=False)
    pb_q = page_bytes(cfg, ps, quant=True)
    budget = args.fp_pages * pb_fp              # the shared HBM budget
    pages_q = budget // pb_q
    per_tok = kv_bytes_per_token(cfg)

    # oversubscribe: enough identical-length requests to fill the bigger
    # pool twice over, so the pool (not the wave) bounds concurrency
    need = -(-(args.prompt_len + args.new_tokens) // ps)
    nreq = max(4, 2 * int(pages_q) // need)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, args.prompt_len)))
               for _ in range(nreq)]
    max_len = ps * (need + 1)

    print(f"[kv-compress] arch={args.arch} dtype={cfg.dtype} "
          f"page_size={ps} prompt_len={args.prompt_len} "
          f"new={args.new_tokens} x {nreq} requests")
    print(f"  HBM budget {budget:,}B -> fp pool {args.fp_pages} pages "
          f"({pb_fp:,}B/page), int8 pool {pages_q} pages "
          f"({pb_q:,}B/page)")

    def build(quant):
        pool = pages_q if quant else args.fp_pages
        return ServeEngine(cfg, params, max_batch=nreq, max_len=max_len,
                           page_size=ps, num_pages=int(pool),
                           kv_quant=quant)

    # --- capacity: peak concurrent residents on the fixed budget -------
    conc_fp = peak_concurrency(build(False), prompts, args.new_tokens)
    conc_q = peak_concurrency(build(True), prompts, args.new_tokens)
    ratio = conc_q / max(1, conc_fp)
    req_bytes_fp = need * pb_fp
    req_bytes_q = need * pb_q
    print(f"  KV HBM per request at peak length ({need} pages): "
          f"fp {req_bytes_fp:,}B vs int8 {req_bytes_q:,}B "
          f"({req_bytes_fp / req_bytes_q:.2f}x smaller)")
    print(f"  peak concurrent requests on the budget: fp {conc_fp} vs "
          f"int8 {conc_q} ({ratio:.2f}x)")

    # --- throughput: the dequant must ride the gather for ~free --------
    eng_fp, eng_q = build(False), build(True)
    wave = prompts[: max(2, conc_fp)]           # fits both engines
    drive(eng_fp, wave, args.new_tokens)        # compile pass
    drive(eng_q, wave, args.new_tokens)
    passes = 1 if args.tiny else 3
    tps_fp = max(drive(eng_fp, wave, args.new_tokens)[0]
                 for _ in range(passes))
    tps_q = max(drive(eng_q, wave, args.new_tokens)[0]
                for _ in range(passes))
    print(f"  steady-state decode: fp {tps_fp:.1f} tok/s vs int8 "
          f"{tps_q:.1f} tok/s ({tps_q / tps_fp:.2f}x)")
    print(f"  compiles (prefill/decode): fp {eng_fp.prefill_compiles}/"
          f"{eng_fp.decode_compiles} vs int8 {eng_q.prefill_compiles}/"
          f"{eng_q.decode_compiles}")

    # quantization changes the cache dtype, never the compile-key space
    assert eng_q.decode_compiles == eng_fp.decode_compiles, \
        "int8 pages changed the decode compile count"
    assert eng_q.prefill_compiles == eng_fp.prefill_compiles, \
        "int8 pages changed the prefill compile count"
    if args.tiny:
        assert ratio > 1.0, (
            f"int8 pages must raise capacity on a fixed budget "
            f"(got {ratio:.2f}x)")
    else:
        assert ratio >= 1.8, (
            f"capacity ratio {ratio:.2f}x missed the >=1.8x target")

    out = {"bench": "kv_compress", "arch": args.arch, "dtype": cfg.dtype,
           "tiny": bool(args.tiny),
           "workload": {"page_size": ps, "prompt_len": args.prompt_len,
                        "new_tokens": args.new_tokens, "requests": nreq,
                        "kv_bytes_per_token_fp": per_tok},
           "hbm_budget_bytes": int(budget),
           "page_bytes": {"fp": int(pb_fp), "int8": int(pb_q)},
           "pool_pages": {"fp": int(args.fp_pages), "int8": int(pages_q)},
           "request_kv_bytes": {"fp": int(req_bytes_fp),
                                "int8": int(req_bytes_q)},
           "peak_concurrent": {"fp": int(conc_fp), "int8": int(conc_q),
                               "ratio": float(ratio)},
           "decode_tok_s": {"fp": float(tps_fp), "int8": float(tps_q)},
           "compiles": {"fp": [eng_fp.prefill_compiles,
                               eng_fp.decode_compiles],
                        "int8": [eng_q.prefill_compiles,
                                 eng_q.decode_compiles]}}
    with open("BENCH_kvq.json", "w") as f:
        json.dump(out, f, indent=2)
    print("  wrote BENCH_kvq.json")


if __name__ == "__main__":
    main()
