"""Paged vs dense KV-cache serving: HBM reservation + steady-state tok/s.

The dense submit/step engine reserves a ``(max_batch, Hkv, max_len, D)``
cache row per slot — every request pays for the worst case, so a
mixed-length batch wastes almost all of it (the PagedAttention
fragmentation argument).  The paged engine stores KV in fixed-size pages
handed out by a ``PageAllocator``: a request holds ``ceil(len /
page_size)`` pages, so its reservation tracks its *true* length.

This benchmark drives both engines over the same mixed-length request set
and reports, per request, the KV HBM bytes reserved at its peak length —
dense is O(max_len) per request, paged is O(true length) — plus
steady-state tokens/sec for both so the gather shows up (or doesn't) in
throughput.

Backend note: on TPU (tl_pallas) the page gather rides the kernel's
BlockSpec index maps — the mandatory HBM->VMEM DMA is simply redirected,
so paging is free and the dead-page skip makes short rows *cheaper* than
dense.  The XLA-CPU fallback measured here has no index-map DMA tier, so
it feeds the page gather into the flash scan as one chunk per page
(`xla_flash(prechunked=True)`) — one extra pass of KV traffic per layer,
a few percent of a decode step at these scales (within run-to-run noise;
steady-state below is best-of-N warm passes to filter scheduler jitter).

The long-context section drives a small batch against a deep paged cache
— the launch-starved decode regime — and reports steady-state tok/s with
reason-chosen split-KV decode (Flash-Decoding) vs forced
``num_splits=1``.

The shared-prefix section drives the same engine over N requests with a
common prompt prefix (the system-prompt / few-shot workload), cold
(prefix cache off) vs warm (on): the prefix cache maps cached pages into
each follower's block table, so prefill compute — tokens actually pushed
through the model, the FLOPs proxy — compile count, and pages allocated
all drop, while the tokens stay bit-identical.

    PYTHONPATH=src python benchmarks/paged_kv.py --arch deepseek-7b
    PYTHONPATH=src python benchmarks/paged_kv.py --tiny     # CI smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


def kv_bytes_per_token(cfg) -> int:
    """KV-cache bytes one token occupies across all attention layers."""
    kinds, nper = T.period_spec(cfg)
    bytes_per = 2 if cfg.dtype in ("bf16", "f16") else 4
    if cfg.mla:
        row = (cfg.kv_lora_rank + cfg.rope_head_dim) * bytes_per
    else:
        row = 2 * cfg.num_kv_heads * cfg.head_dim * bytes_per   # K and V
    n_attn = sum(k in ("attn", "self") for k in kinds) * nper
    n_attn += cfg.first_k_dense if not getattr(cfg, "rwkv", False) else 0
    return row * n_attn


def drive(engine: ServeEngine, prompts, new_tokens):
    """Submit everything, drain, return (tok/s, peak per-request lens)."""
    for p in prompts:
        engine.submit(p, max_new_tokens=new_tokens)
    t0 = time.perf_counter()
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    produced = sum(len(r.tokens) for r in done)
    peak = {r.uid: len(r.prompt) + len(r.tokens) for r in done}
    return produced / dt, peak, done


def long_context_report(cfg, params, args):
    """The long-context wave: a small batch decoding against deep KV —
    the workload where ``bsz * heads`` under-fills the machine and the
    reasoned split-KV decode (Flash-Decoding) buys its parallelism back.
    Reports pure-decode steady-state tok/s, reason-chosen splits vs
    forced ``num_splits=1``."""
    from serve_decode import steady_decode_tps   # shared timing loop

    rng = np.random.default_rng(2)
    b = 1 if args.tiny else 2
    plen = args.max_len * 3 // 4
    new = args.new_tokens
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, plen)))
               for _ in range(b)]

    def run(num_splits):
        eng = ServeEngine(cfg, params, max_batch=b, max_len=args.max_len,
                          page_size=args.page_size, num_splits=num_splits)
        steady_decode_tps(eng, prompts, new)      # compile pass
        passes = 1 if args.tiny else 3
        best = max(steady_decode_tps(eng, prompts, new)
                   for _ in range(passes))
        chosen = eng._decode_splits(eng._decode_bucket(plen + 1), b,
                                    paged_dispatch=True)
        return best, chosen

    tps_one, _ = run(1)
    tps_auto, chosen = run(None)
    print(f"  long-context wave: batch {b} x {plen}-token context, "
          f"steady-state decode")
    print(f"    forced num_splits=1: {tps_one:.1f} tok/s; reason-chosen "
          f"({chosen} splits): {tps_auto:.1f} tok/s "
          f"({tps_auto / tps_one:.2f}x)")


def shared_prefix_report(cfg, params, args):
    """N requests, ~75% common prefix, cold (prefix cache off) vs warm —
    two waves, so wave 2 shows the steady state: every shape is traced,
    and the warm engine prefills only each prompt's un-shared suffix."""
    rng = np.random.default_rng(1)
    nreq = 4 if args.tiny else 8
    plen = max(2 * args.page_size, args.max_len // 4)
    pre_len = plen * 3 // 4                          # 75% shared
    pre = list(map(int, rng.integers(0, cfg.vocab_size, pre_len)))

    def wave():
        return [pre + list(map(int, rng.integers(0, cfg.vocab_size,
                                                 plen - pre_len)))
                for _ in range(nreq)]

    waves = [wave(), wave()]         # identical waves for both engines

    def run(prefix_cache, budget=None):
        eng = ServeEngine(cfg, params, max_batch=nreq,
                          max_len=args.max_len, page_size=args.page_size,
                          prefix_cache=prefix_cache, prefill_budget=budget)
        toks, stats = {}, []
        for w in waves:
            for p in w:
                eng.submit(p, max_new_tokens=args.new_tokens)
            done = eng.run_until_drained()
            toks.update({r.uid: list(r.tokens) for r in done})
            stats.append((eng.prefill_tokens, eng.prefill_compiles,
                          eng.allocator.alloc_count))
        return eng, toks, stats

    cold, cold_toks, cold_stats = run(False)
    warm, warm_toks, warm_stats = run(True)
    assert cold_toks == warm_toks, "prefix reuse changed the tokens!"
    hit_rate = warm.prefix_hit_tokens / max(
        1, warm.prefix_hit_tokens + warm.prefill_tokens)
    print(f"  shared-prefix workload: 2 waves x {nreq} requests x {plen} "
          f"tokens, {pre_len} shared ({pre_len / plen:.0%})")
    print(f"    prefix-cache hit rate: {hit_rate:.0%} of prompt tokens "
          f"({warm.prefix_hit_tokens} cached vs {warm.prefill_tokens} "
          "computed); COW copies: "
          f"{warm.cow_count}")
    for i, name in enumerate(["wave 1 (cold cache)", "wave 2 (steady)"]):
        ct, cc, ca = cold_stats[i]
        wt, wc, wa = warm_stats[i]
        if i:
            pt, pc, pa = cold_stats[0]
            wt0, wc0, wa0 = warm_stats[0]
            ct, cc, ca = ct - pt, cc - pc, ca - pa
            wt, wc, wa = wt - wt0, wc - wc0, wa - wa0
        print(f"    {name}: prefill tokens (FLOPs proxy) cold {ct} / warm "
              f"{wt} ({ct / max(1, wt):.1f}x less), new prefill compiles "
              f"cold {cc} / warm {wc}, pages allocated cold {ca} / warm "
              f"{wa} ({ca / max(1, wa):.1f}x less)")
    assert warm.prefill_tokens < cold.prefill_tokens
    assert warm.allocator.alloc_count < cold.allocator.alloc_count
    assert warm_stats[1][1] - warm_stats[0][1] == 0, \
        "steady-state wave must not retrace prefill"

    # budgeted interleaving admits the whole wave *before* any page is
    # registered, so admission-time prefix probes all miss — the
    # in-flight radix dedup recovers the sharing instead: the leader
    # publishes full pages as its chunks land and the followers adopt
    # them mid-prefill rather than recomputing the common prefix
    wi, wi_toks, wi_stats = run(True, budget=args.page_size)
    assert wi_toks == cold_toks, "interleaving changed the tokens!"
    assert wi.inflight_dedup_pages > 0, \
        "batch-admitted shared prefixes must dedup in flight"
    print(f"    budgeted interleaving (budget={args.page_size}): wave-1 "
          f"prefill tokens {wi_stats[0][0]} (vs warm sequential "
          f"{warm_stats[0][0]}), {wi.inflight_dedup_pages} pages adopted "
          f"in-flight from the leader, {wi.preemptions} preemptions")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--lens", type=int, nargs="+",
                    default=[8, 24, 60, 150, 300],
                    help="mixed prompt lengths (the fragmentation case)")
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale smoke run for CI")
    args = ap.parse_args()
    if args.tiny:
        args.max_len, args.page_size = 64, 16
        args.new_tokens, args.lens = 4, [5, 20]

    cfg = registry.get_reduced(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in args.lens]
    per_tok = kv_bytes_per_token(cfg)
    max_batch = len(prompts)

    print(f"[paged-kv] arch={args.arch} max_len={args.max_len} "
          f"page_size={args.page_size} prompts={args.lens} "
          f"new={args.new_tokens}  ({per_tok} KV bytes/token)")

    warm_passes = 1 if args.tiny else 3

    def measure(engine):
        """Cold pass compiles; steady state = best of the warm passes
        (each pass is short, so max filters scheduler noise)."""
        drive(engine, prompts, args.new_tokens)
        best, peak = 0.0, None
        for _ in range(warm_passes):
            tps, peak, _ = drive(engine, prompts, args.new_tokens)
            best = max(best, tps)
        return best, peak

    dense = ServeEngine(cfg, params, max_batch=max_batch,
                        max_len=args.max_len, paged=False)
    tps_d, peak_d = measure(dense)

    paged = ServeEngine(cfg, params, max_batch=max_batch,
                        max_len=args.max_len, page_size=args.page_size)
    tps_p, peak_p = measure(paged)

    dense_per_req = args.max_len * per_tok
    print(f"  {'request':>8} {'peak len':>9} {'dense reserved':>15} "
          f"{'paged reserved':>15} {'saved':>7}")
    tot_d = tot_p = 0
    ps = args.page_size
    # second-wave uids in the paged engine start after the first wave
    for i, n in enumerate(sorted(peak_p)):
        peak = peak_p[n]
        pages = -(-peak // ps)
        paged_per_req = pages * ps * per_tok
        tot_d += dense_per_req
        tot_p += paged_per_req
        print(f"  {i:>8} {peak:>9} {dense_per_req:>14,}B "
              f"{paged_per_req:>14,}B {1 - paged_per_req / dense_per_req:>6.0%}")
    print(f"  total KV reserved: dense {tot_d:,}B "
          f"(O(max_len) x {max_batch} slots) vs paged {tot_p:,}B "
          f"(O(true length)) -> {tot_d / tot_p:.1f}x less HBM held")
    print(f"  steady-state throughput: dense {tps_d:.1f} tok/s, "
          f"paged {tps_p:.1f} tok/s ({tps_p / tps_d:.2f}x)")
    print(f"  decode compiles: dense {dense.decode_compiles}, "
          f"paged {paged.decode_compiles} (both bounded by buckets)")

    long_context_report(cfg, params, args)
    shared_prefix_report(cfg, params, args)


if __name__ == "__main__":
    main()
