"""Benchmark harness: one section per paper table/figure.

  Table 1  -> attn_variants   (MHA/GQA/MQA x seqlen x causal)
  Table 2  -> mla             (MLA latent kernel vs naive)
  Table 5  -> naive_vs_tl     (vanilla implementation vs TL pipeline)
  Table3/4/App.B -> ablation  (one-stage vs two-stage, dev cost)
  Dry-run  -> roofline_table  (40 cells x 2 meshes from results/dryrun.json)

``python -m benchmarks.run [--full] [--only <name>]``
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale seqlens (slow on CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (ablation, attn_variants, fp8_case_study, mla,
                   naive_vs_tl, nsa_window, roofline_table)
    sections = [
        ("attn_variants (paper Table 1)", lambda: attn_variants.run(args.full)),
        ("mla (paper Table 2)", lambda: mla.run(args.full)),
        ("naive_vs_tl (paper Table 5)", lambda: naive_vs_tl.run(args.full)),
        ("ablation (paper Tables 3/4, App. B)", ablation.run),
        ("fp8_case_study (paper Table 6)", fp8_case_study.run),
        ("nsa_window (paper Table 9)", lambda: nsa_window.run(args.full)),
        ("roofline_table baseline (results/dryrun.json)",
         lambda: roofline_table.run("results/dryrun.json")),
        ("roofline_table optimized (results/dryrun_opt.json)",
         lambda: roofline_table.run("results/dryrun_opt.json")),
    ]
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        fn()
        print(f"----- {name}: {time.perf_counter()-t0:.1f}s -----")


if __name__ == "__main__":
    main()
