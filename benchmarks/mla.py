"""Paper Table 2 analogue: MLA with causal mask (DeepSeek-V3 geometry).

torch-style naive (materialised per-head attention over up-projected K/V)
vs the TL-generated absorbed-latent kernel — the kernel reads the latent
cache ONCE for both GEMMs, which is MLA's entire memory argument.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.spec import AttnSpec
from repro.kernels import ops
from .common import CsvOut, timeit


def naive_mla(q_latent, c_kv, r):
    """Materialises full scores — the 'torch' row of Table 2."""
    s = jnp.einsum("bhmd,bnd->bhmn", q_latent.astype(jnp.float32),
                   c_kv.astype(jnp.float32)) * ((128 + (q_latent.shape[-1] - r)) ** -0.5)
    m, n = s.shape[-2:]
    mask = jnp.tril(jnp.ones((m, n), bool), n - m)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhmn,bnr->bhmr", p, c_kv[..., :r].astype(jnp.float32))


def run(full: bool = False):
    seqlens = [512, 1024, 2048, 4096, 8192, 16384] if full else [256, 512, 1024]
    heads = 16 if not full else 128      # V3: 128 heads
    r, rr = (128, 32) if not full else (512, 64)
    out = CsvOut(["seqlen", "heads", "kv_lora", "naive_ms", "tl_ms",
                  "est_v5e_tflops"])
    rng = np.random.default_rng(0)
    for s in seqlens:
        b = max(1, 2048 // s)
        ql = jnp.asarray(rng.standard_normal((b, heads, s, r + rr)) * 0.3,
                         jnp.float32)
        c = jnp.asarray(rng.standard_normal((b, s, r + rr)) * 0.3,
                        jnp.float32)
        t_naive = timeit(lambda: naive_mla(ql, c, r))
        t_tl = timeit(lambda: ops.mla_attention(
            ql, c, kv_lora_rank=r, rope_head_dim=rr))
        spec = AttnSpec.mla(heads, r, rr)
        est = autotune.tune(spec, s, s, "v5e").efficiency * 197.0
        out.row(s, heads, r, f"{t_naive*1e3:.1f}", f"{t_tl*1e3:.1f}",
                f"{est:.1f}")


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
