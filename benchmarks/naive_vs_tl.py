"""Paper Table 5 analogue: vanilla/CoT-style implementation vs LLM-TL.

The paper's "vanilla LLM" and "+CoT" rows are unoptimised implementations
(materialised scores, no blocking/fusion); "+LLM-TL" is the generated fused
kernel.  Here the same comparison is made structurally:

  naive      — materialised S = QK^T softmax einsum (O(s^2) memory)
  tl_kernel  — TL pipeline output (blocked, fused, online softmax)

reporting peak intermediate bytes (the OOM column of Table 1/5: naive OOMs
at 16k in the paper) and the v5e roofline projection.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import autotune
from repro.core.reason import _vmem_bytes
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref
from .common import CsvOut, timeit


def run(full: bool = False):
    seqlens = [512, 1024, 2048, 4096, 8192, 16384] if full else [256, 512, 1024, 2048]
    heads, d = 16, 64
    out = CsvOut(["seqlen", "naive_ms", "tl_ms", "naive_peak_mb",
                  "tl_onchip_kb", "est_v5e_tflops"])
    rng = np.random.default_rng(0)
    for s in seqlens:
        b = max(1, 2048 // s)
        q = jnp.asarray(rng.standard_normal((b, heads, s, d)) * 0.5,
                        jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, heads, s, d)) * 0.5,
                        jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, heads, s, d)) * 0.5,
                        jnp.float32)
        t_naive = timeit(lambda: ref.attention(q, k, v, causal=True))
        t_tl = timeit(lambda: ops.flash_attention(q, k, v, causal=True))
        naive_peak = b * heads * s * s * 4          # materialised scores
        spec = AttnSpec.mha(heads, d)
        tune = autotune.tune(spec, s, s, "v5e")
        onchip = _vmem_bytes(spec, tune.blocks.bm, tune.blocks.bn)
        out.row(s, f"{t_naive*1e3:.1f}", f"{t_tl*1e3:.1f}",
                f"{naive_peak/2**20:.1f}", f"{onchip/2**10:.1f}",
                f"{tune.efficiency*197.0:.1f}")


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
