"""Render the 40-cell x 2-mesh roofline table from results/dryrun.json
(produced by repro.launch.dryrun) as the EXPERIMENTS.md §Roofline table."""

from __future__ import annotations

import json
import os

from repro.roofline.report import HEADER


def fmt_row(r) -> str:
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — "
                f"| — | — | — |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | — "
                f"| — | — | — |")
    t = r["roofline"]
    fits = "" if r["memory_per_device_gib"] <= 16 else " **(>16G)**"
    return (f"| {t['arch']} | {t['shape']} | {t['mesh']} | "
            f"{t['compute_s']*1e3:.0f} | {t['memory_s']*1e3:.0f} | "
            f"{t['collective_s']*1e3:.0f} | {t['dominant']} | "
            f"{t['useful_ratio']:.2f} | {t['roofline_frac']:.3f} | "
            f"{r['memory_per_device_gib']:.1f}{fits} |")


def run(path: str = "results/dryrun.json"):
    if not os.path.exists(path):
        print(f"(no {path}; run python -m repro.launch.dryrun --all "
              f"--both-meshes --out {path})")
        return
    rows = json.load(open(path))
    order = {"16x16": 0, "2x16x16": 1}
    rows.sort(key=lambda r: (order.get(r["mesh"], 9), r["arch"], r["shape"]))
    print(HEADER.replace("roofline frac |", "roofline frac | mem/dev GiB |")
          .replace("|---|---|---|---|---|---|---|---|---|",
                   "|---|---|---|---|---|---|---|---|---|---|"))
    for r in rows:
        print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"\n{len(ok)} compiled cells, "
          f"{sum(1 for r in rows if r['status'] == 'skip')} documented skips, "
          f"{sum(1 for r in rows if r['status'] == 'error')} errors")


if __name__ == "__main__":
    run()
