"""Decode-path serving benchmark: per-step recompilation vs bucketed
runtime-length decode.

The seed engine specialised the decode jit on ``cache_len`` (a static TL
parameter), so every generated token retraced and recompiled — T tokens,
T compiles.  The bucketed engine compiles one decode step per power-of-two
length bucket and feeds the true cache length in as runtime data, so the
same T tokens cost at most log2(max_len) compiles.  This benchmark measures
both regimes on the same model/params and reports compile counts and
steady-state tokens/sec.

    PYTHONPATH=src python benchmarks/serve_decode.py --arch deepseek-7b \
        --new-tokens 32
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


def legacy_generate(cfg, params, prompts, max_new_tokens):
    """The seed serving loop: decode jitted with *static* cache_len, so the
    kernel is re-specialised at every step.  Returns (tokens, compiles,
    decode_seconds)."""
    compiles = [0]

    @functools.partial(jax.jit, static_argnames=("cache_len",))
    def decode(params, tok, caches, cache_len):
        compiles[0] += 1
        logits, _, caches = T.apply(params, tok, cfg, caches=caches,
                                    cache_len=cache_len)
        return logits[:, -1], caches

    b = len(prompts)
    lens = [len(p) for p in prompts]
    toks = jnp.asarray(prompts, jnp.int32)
    caches = T.init_caches(cfg, b, 256)
    logits, _, caches = T.apply(params, toks, cfg, caches=caches, cache_len=0)
    step_logits = logits[jnp.arange(b), jnp.asarray(lens) - 1]

    out = np.zeros((b, max_new_tokens), np.int32)
    cache_len = lens[0]
    t0 = time.perf_counter()
    for t in range(max_new_tokens):
        tok = jnp.argmax(step_logits, axis=-1)
        out[:, t] = np.asarray(tok)
        step_logits, caches = decode(params, tok[:, None].astype(jnp.int32),
                                     caches, cache_len)
        cache_len += 1
    jax.block_until_ready(step_logits)
    return out, compiles[0], time.perf_counter() - t0


def bucketed_generate(engine, prompts, max_new_tokens):
    t0 = time.perf_counter()
    res = engine.generate(prompts, max_new_tokens=max_new_tokens)
    dt = time.perf_counter() - t0
    return res.tokens, engine.decode_compiles, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--attn-impl", default="xla_flash",
                    choices=["tl_pallas", "xla_flash", "naive"])
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale smoke run for CI")
    args = ap.parse_args()
    if args.tiny:
        args.batch, args.prompt_len, args.new_tokens = 2, 12, 4

    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          args.prompt_len)))
               for _ in range(args.batch)]
    n_tok = args.batch * args.new_tokens

    print(f"[serve-decode] arch={args.arch} attn={args.attn_impl} "
          f"batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    toks_l, compiles_l, dt_l = legacy_generate(cfg, params, prompts,
                                               args.new_tokens)
    print(f"  legacy (static cache_len): {compiles_l} decode compiles, "
          f"{dt_l:.2f}s cold, {n_tok / dt_l:.1f} tok/s incl. compiles")
    # warm pass is meaningless for legacy: every step recompiles anyway

    engine = ServeEngine(cfg, params, max_batch=args.batch, max_len=256)
    toks_b, compiles_b, dt_b = bucketed_generate(engine, prompts,
                                                 args.new_tokens)
    print(f"  bucketed (runtime cache_len): {compiles_b} decode compiles, "
          f"{dt_b:.2f}s cold, {n_tok / dt_b:.1f} tok/s incl. compiles")
    _, compiles_w, dt_w = bucketed_generate(engine, prompts, args.new_tokens)
    print(f"  bucketed warm (0 new compiles: "
          f"{compiles_w - compiles_b == 0}): "
          f"{dt_w:.2f}s, {n_tok / dt_w:.1f} tok/s steady-state")
    if not np.array_equal(toks_l, toks_b):
        print("  WARNING: token mismatch between regimes")
    print(f"  compile reduction: {compiles_l}x -> {compiles_b}x "
          f"per {args.new_tokens}-token generation")


if __name__ == "__main__":
    main()
