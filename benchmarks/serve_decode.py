"""Decode-path serving benchmark: per-step recompilation vs bucketed
runtime-length decode, plus the split-KV context-length sweep.

The seed engine specialised the decode jit on ``cache_len`` (a static TL
parameter), so every generated token retraced and recompiled — T tokens,
T compiles.  The bucketed engine compiles one decode step per power-of-two
length bucket and feeds the true cache length in as runtime data, so the
same T tokens cost at most log2(max_len) compiles.  This benchmark measures
both regimes on the same model/params and reports compile counts and
steady-state tokens/sec.

    PYTHONPATH=src python benchmarks/serve_decode.py --arch deepseek-7b \
        --new-tokens 32

``--sweep`` instead drives the paged submit/step engine across KV context
lengths at batch {1, 4} and reports *pure decode* steady-state tok/s
(admission/prefill excluded) with reason-chosen split-KV decode vs forced
``num_splits=1`` — the Flash-Decoding win: small batches over long
contexts under-fill the machine, splitting the KV axis fills it.  These
rows seed the repo's BENCH trajectory.

    PYTHONPATH=src python benchmarks/serve_decode.py --sweep
    PYTHONPATH=src python benchmarks/serve_decode.py --sweep --tiny  # CI

``--interleave`` A/Bs the SLO scheduler: the same mixed workload — one
long prompt plus a tail of short high-priority prompts — through
whole-prompt admission (``prefill_budget=None``) and budgeted chunked
interleaving, asserting token identity, reporting TTFT/TPOT percentiles
from the engine's own ``stats()``, and writing the rows to
``BENCH_serve.json``.

    PYTHONPATH=src python benchmarks/serve_decode.py --interleave
    PYTHONPATH=src python benchmarks/serve_decode.py --interleave --tiny

``--spec`` A/Bs speculative decoding: a draft-length x acceptance-rate
sweep against the non-speculative engine on the same workload, using an
*oracle* draft source — it proposes the true greedy continuation
(captured from the reference run) with each token corrupted at
probability ``1 - rate``, so the sweep dials acceptance synthetically
while the engine's verify/rollback machinery runs for real.  Token
identity is asserted in every arm (speculation must never change the
stream), steady-state tok/s and the engine's acceptance/rollback
counters land in ``BENCH_spec.json``, and at full scale the run asserts
the headline contract: >= 1.5x at >= 0.7 acceptance, <= 1.15x slowdown
at zero acceptance.

    PYTHONPATH=src python benchmarks/serve_decode.py --spec
    PYTHONPATH=src python benchmarks/serve_decode.py --spec --tiny

``--mesh N`` A/Bs tensor-parallel sharded serving: the same decode
workload through ``ServeEngine(mesh=...)`` at model_axis {1, 2, 4}
(clamped to N), each arm in its own subprocess with
``--xla_force_host_platform_device_count`` so the mesh is real.  Token
identity across arms is asserted (sharding must never change the
stream), steady-state tok/s per arm lands in ``BENCH_shard.json``, and
at full scale the run asserts the headline contract: >= 1.5x from
model_axis 1 -> 4.  ``--tiny`` (CI, forced *host* devices timeshare one
CPU) only warns — the identity assert still holds.

    PYTHONPATH=src python benchmarks/serve_decode.py --mesh 4
    PYTHONPATH=src python benchmarks/serve_decode.py --mesh 4 --tiny
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


def legacy_generate(cfg, params, prompts, max_new_tokens):
    """The seed serving loop: decode jitted with *static* cache_len, so the
    kernel is re-specialised at every step.  Returns (tokens, compiles,
    decode_seconds)."""
    compiles = [0]

    @functools.partial(jax.jit, static_argnames=("cache_len",))
    def decode(params, tok, caches, cache_len):
        compiles[0] += 1
        logits, _, caches = T.apply(params, tok, cfg, caches=caches,
                                    cache_len=cache_len)
        return logits[:, -1], caches

    b = len(prompts)
    lens = [len(p) for p in prompts]
    toks = jnp.asarray(prompts, jnp.int32)
    caches = T.init_caches(cfg, b, 256)
    logits, _, caches = T.apply(params, toks, cfg, caches=caches, cache_len=0)
    step_logits = logits[jnp.arange(b), jnp.asarray(lens) - 1]

    out = np.zeros((b, max_new_tokens), np.int32)
    cache_len = lens[0]
    t0 = time.perf_counter()
    for t in range(max_new_tokens):
        tok = jnp.argmax(step_logits, axis=-1)
        out[:, t] = np.asarray(tok)
        step_logits, caches = decode(params, tok[:, None].astype(jnp.int32),
                                     caches, cache_len)
        cache_len += 1
    jax.block_until_ready(step_logits)
    return out, compiles[0], time.perf_counter() - t0


def bucketed_generate(engine, prompts, max_new_tokens):
    t0 = time.perf_counter()
    res = engine.generate(prompts, max_new_tokens=max_new_tokens)
    dt = time.perf_counter() - t0
    return res.tokens, engine.decode_compiles, dt


def steady_decode_tps(engine, prompts, new_tokens):
    """Pure decode steady-state tok/s: submit everything, run the first
    step (admission + prefill + first decode) outside the clock, then
    time the remaining decode steps only."""
    for p in prompts:
        engine.submit(p, max_new_tokens=new_tokens)
    engine.step()   # admission + prefill + first decode, off the clock
    t0 = time.perf_counter()
    tokens = 0
    while engine.active_requests or engine._queue:
        before = sum(len(r.tokens) for r in engine.active_requests)
        fin = engine.step()
        tokens += sum(len(r.tokens) for r in engine.active_requests) \
            + sum(len(r.tokens) for r in fin) - before
    return tokens / (time.perf_counter() - t0)


def sweep(args):
    """tok/s vs KV context length at batch {1, 4}, reason-chosen splits
    vs forced num_splits=1, on the paged submit/step engine."""
    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    lens = [128, 256] if args.tiny else [256, 512, 1024, 2048]
    batches = [1, 2] if args.tiny else [1, 4]
    max_len = max(lens) * 2
    print(f"[serve-decode --sweep] arch={args.arch} attn={args.attn_impl} "
          f"new={args.new_tokens} page=64 (pure decode steady state)")
    print(f"  {'batch':>5} {'kv_len':>7} {'splits=1':>10} "
          f"{'reason':>10} {'chosen':>7} {'speedup':>8}")
    for b in batches:
        for kv_len in lens:
            prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                                  kv_len)))
                       for _ in range(b)]
            row = {}
            for forced in (1, None):
                eng = ServeEngine(cfg, params, max_batch=b,
                                  max_len=max_len, num_splits=forced)
                steady_decode_tps(eng, prompts, args.new_tokens)  # compile
                best = max(steady_decode_tps(eng, prompts,
                                             args.new_tokens)
                           for _ in range(args.passes))
                row[forced] = (best, eng)
            eng = row[None][1]
            chosen = eng._decode_splits(eng._decode_bucket(kv_len + 1), b,
                                        paged_dispatch=True)
            print(f"  {b:>5} {kv_len:>7} {row[1][0]:>9.1f}t "
                  f"{row[None][0]:>9.1f}t {chosen:>7} "
                  f"{row[None][0] / row[1][0]:>7.2f}x")


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else None


def interleave(args):
    """SLO A/B on one workload: a long prompt plus short priority-1
    prompts, whole-prompt admission vs budgeted chunked interleaving.

    The prefix cache is off so the warm-up wave (compiles) cannot feed
    pages to the measured wave; tokens must be identical between modes,
    short-prompt p99 TTFT should drop under interleaving, and aggregate
    tok/s should hold within ~10% (asserted at full scale, warned in
    ``--tiny`` where a single scheduler hiccup swamps the seconds)."""
    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    if args.tiny:
        long_len, short_len, n_short, new, budget, page = 96, 8, 3, 4, 16, 16
    else:
        # decode-heavy mix: the budgeted mode pays ~(long_len / budget)
        # extra decode dispatches while the long prompt chunks, so the
        # decode phase must dominate for the <=10% throughput bound
        long_len, short_len, n_short, new, budget, page = \
            768, 32, 12, 64, 128, 64
    max_len = 2 * max(long_len, 64)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, long_len)))]
    prompts += [list(map(int, rng.integers(0, cfg.vocab_size, short_len)))
                for _ in range(n_short)]
    prios = [0] + [1] * n_short
    warm = [list(map(int, rng.integers(0, cfg.vocab_size, len(p))))
            for p in prompts]

    def run(pf_budget):
        eng = ServeEngine(cfg, params, max_batch=1 + n_short,
                          max_len=max_len, page_size=page,
                          prefix_cache=False, prefill_budget=pf_budget)
        for p, pr in zip(warm, prios):      # warm-up wave: compiles only
            eng.submit(list(p), max_new_tokens=new, priority=pr)
        eng.run_until_drained(max_steps=10_000)
        eng.reset_metrics()
        uids = [eng.submit(list(p), max_new_tokens=new, priority=pr)
                for p, pr in zip(prompts, prios)]
        t0 = time.perf_counter()
        done = eng.run_until_drained(max_steps=10_000)
        dt = time.perf_counter() - t0
        by_uid = {r.uid: r for r in done}
        reqs = [by_uid[u] for u in uids]
        s = eng.stats()
        short_ttft = [r.first_token_time - r.submit_time for r in reqs[1:]]
        return {
            "tok_s": s["generated_tokens"] / dt,
            "wall_s": dt,
            "ttft_long_s": reqs[0].first_token_time - reqs[0].submit_time,
            "ttft_short_p50_s": _pct(short_ttft, 50),
            "ttft_short_p99_s": _pct(short_ttft, 99),
            "stats": s,
        }, [list(r.tokens) for r in reqs]

    print(f"[serve-decode --interleave] arch={args.arch} "
          f"attn={args.attn_impl} long={long_len} "
          f"short={short_len}x{n_short} new={new} budget={budget} "
          f"page={page}")
    row_a, toks_a = run(None)
    row_b, toks_b = run(budget)
    assert toks_a == toks_b, \
        "interleaving changed the tokens — scheduler bug"
    for name, row in (("whole-prompt", row_a), ("interleaved", row_b)):
        s = row["stats"]
        print(f"  {name:>13}: {row['tok_s']:7.1f} tok/s | "
              f"short TTFT p50 {row['ttft_short_p50_s'] * 1e3:7.1f}ms "
              f"p99 {row['ttft_short_p99_s'] * 1e3:7.1f}ms | "
              f"long TTFT {row['ttft_long_s'] * 1e3:7.1f}ms | "
              f"TPOT p50 {s['tpot_s']['p50'] * 1e3:6.1f}ms | "
              f"{s['steps']} steps, {s['decode_compiles']} decode / "
              f"{s['prefill_compiles']} prefill compiles")
    speed = row_b["ttft_short_p99_s"] / row_a["ttft_short_p99_s"]
    loss = 1.0 - row_b["tok_s"] / row_a["tok_s"]
    print(f"  short p99 TTFT x{speed:.2f} vs whole-prompt "
          f"({'better' if speed < 1 else 'worse'}); "
          f"aggregate tok/s {'loss' if loss > 0 else 'gain'} "
          f"{abs(loss) * 100:.1f}%")
    if args.tiny:
        if speed >= 1.0 or loss > 0.10:
            print("  WARNING: tiny-scale numbers missed the SLO targets "
                  "(noise-dominated at this scale)")
    else:
        assert speed < 1.0, "interleaving must cut short-prompt p99 TTFT"
        assert loss <= 0.10, \
            f"aggregate throughput loss {loss * 100:.1f}% exceeds 10%"
    out = {"bench": "serve_interleave", "arch": args.arch,
           "attn_impl": args.attn_impl, "tiny": bool(args.tiny),
           "workload": {"long_len": long_len, "short_len": short_len,
                        "n_short": n_short, "new_tokens": new,
                        "prefill_budget": budget, "page_size": page},
           "whole_prompt": row_a, "interleaved": row_b}
    with open("BENCH_serve.json", "w") as f:
        json.dump(out, f, indent=2)
    print("  wrote BENCH_serve.json")


class OracleProposer:
    """Synthetic draft source for the ``--spec`` sweep: proposes the true
    greedy continuation (captured from a non-speculative reference run),
    corrupting each token with probability ``1 - rate`` — so per-position
    acceptance is ~``rate`` by construction, while the verify kernel,
    the accept/reject logic, and the page rollback all run for real.
    Deterministic per (seed, call order); keyed by the prompt (fixed
    prompt length), so it works across engine instances."""

    def __init__(self, plen, streams, rate, vocab, seed=0):
        self.plen = plen
        self.streams = streams          # {prompt tuple: greedy stream}
        self.rate = float(rate)
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)

    def propose(self, uid, history, k):
        stream = self.streams.get(tuple(history[:self.plen]))
        if stream is None:
            return []
        t = len(history) - self.plen    # tokens committed so far
        out = []
        for tok in stream[t:t + k]:
            keep = self.rng.random() < self.rate
            out.append(int(tok) if keep else int((tok + 1) % self.vocab))
        return out


def spec(args):
    """Speculative-decode A/B: draft length K x synthetic acceptance rate
    vs the non-speculative engine, token identity asserted, rows written
    to BENCH_spec.json."""
    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    if args.tiny:
        batch, plen, new, page, ks, rates = 2, 12, 8, 16, (4,), (0.0, 1.0)
    else:
        batch, plen, new, page, ks, rates = \
            4, 64, 96, 64, (4, 8), (0.0, 0.3, 0.7, 1.0)
    max_len = _pow2_at_least(plen + new + page)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, plen)))
               for _ in range(batch)]

    def make_engine(spec_on, proposer=None, draft_k=4):
        # the prefix cache is off so the warm-up wave (compiles) cannot
        # feed pages to the measured waves
        eng = ServeEngine(cfg, params, max_batch=batch, max_len=max_len,
                          page_size=page, prefix_cache=False,
                          spec_decode=spec_on, draft_k=draft_k,
                          draft_proposer=proposer)
        for p in prompts:                       # warm wave: compiles only
            eng.submit(list(p), max_new_tokens=new)
        eng.run_until_drained(max_steps=50_000)
        eng.reset_metrics()
        return eng

    def one_pass(eng):
        uids = [eng.submit(list(p), max_new_tokens=new) for p in prompts]
        t0 = time.perf_counter()
        done = eng.run_until_drained(max_steps=50_000)
        dt = time.perf_counter() - t0
        by = {r.uid: r for r in done}
        return [list(by[u].tokens) for u in uids], batch * new / dt

    print(f"[serve-decode --spec] arch={args.arch} attn={args.attn_impl} "
          f"batch={batch} prompt={plen} new={new} page={page} "
          f"(steady-state, oracle drafts, paired passes)")
    base_eng = make_engine(False)
    ref, base_tps = one_pass(base_eng)
    streams = {tuple(p): t for p, t in zip(prompts, ref)}
    print(f"  baseline (no spec): {base_tps:8.1f} tok/s (first pass)")
    print(f"  {'K':>3} {'rate':>5} {'tok/s':>9} {'speedup':>8} "
          f"{'acc p50':>8} {'steps':>6} {'rollback':>9}")
    arms = []
    for k in ks:
        for rate in rates:
            prop = OracleProposer(plen, streams, rate, cfg.vocab_size,
                                  seed=17)
            eng = make_engine(True, proposer=prop, draft_k=k)
            # paired passes: the baseline re-runs adjacent to every spec
            # pass so machine-load drift cancels out of the ratio (the
            # box this measures on is shared; absolute tok/s wanders
            # ~20% between minutes, ratios in the same window do not)
            best_s = best_b = 0.0
            toks = None
            for _ in range(args.passes):
                _, tps_b = one_pass(base_eng)
                toks, tps_s = one_pass(eng)
                best_b = max(best_b, tps_b)
                best_s = max(best_s, tps_s)
            assert toks == ref, \
                f"speculation changed the tokens at K={k} rate={rate}"
            s = eng.stats()
            arm = {"draft_k": k, "rate": rate, "tok_s": best_s,
                   "paired_baseline_tok_s": best_b,
                   "speedup": best_s / best_b,
                   "steps": s["steps"],
                   "drafted_tokens": s["drafted_tokens"],
                   "accepted_tokens": s["accepted_tokens"],
                   "rollback_pages": s["rollback_pages"],
                   "acceptance_rate": s["acceptance_rate"],
                   "verify_compiles": s["verify_compiles"]}
            arms.append(arm)
            p50 = s["acceptance_rate"]["p50"]
            print(f"  {k:>3} {rate:>5.2f} {best_s:>8.1f}t "
                  f"{arm['speedup']:>7.2f}x "
                  f"{(p50 if p50 is not None else -1):>8.2f} "
                  f"{s['steps']:>6} {s['rollback_pages']:>9}")

    high = max(a["speedup"] for a in arms if a["rate"] >= 0.7)
    slow = max(1.0 / a["speedup"] for a in arms if a["rate"] == 0.0)
    print(f"  best speedup at >=0.7 acceptance: {high:.2f}x; "
          f"worst zero-acceptance slowdown: {slow:.2f}x")
    if args.tiny:
        if high < 1.5 or slow > 1.15:
            print("  WARNING: tiny-scale numbers missed the speculative "
                  "targets (noise-dominated at this scale)")
    else:
        assert high >= 1.5, \
            f"speculation must win >=1.5x at high acceptance, got {high:.2f}x"
        assert slow <= 1.15, \
            f"zero-acceptance overhead {slow:.2f}x exceeds the 1.15x bound"
    out = {"bench": "serve_spec_decode", "arch": args.arch,
           "attn_impl": args.attn_impl, "tiny": bool(args.tiny),
           "workload": {"batch": batch, "prompt_len": plen,
                        "new_tokens": new, "page_size": page,
                        "max_len": max_len},
           "baseline_tok_s": base_tps, "arms": arms,
           "summary": {"speedup_at_high_acceptance": high,
                       "zero_acceptance_slowdown": slow}}
    with open("BENCH_spec.json", "w") as f:
        json.dump(out, f, indent=2)
    print("  wrote BENCH_spec.json")


def _pow2_at_least(n):
    b = 64
    while b < n:
        b *= 2
    return b


def _mesh_workload(args):
    if args.tiny:
        return dict(batch=2, plen=24, new=8, page=16)
    return dict(batch=4, plen=512, new=64, page=64)


def mesh_child(args):
    """One ``--mesh`` arm, inside the forced-device subprocess: serve the
    workload on a (devices/m, m) mesh (m=1 keeps the single-device
    engine as the true baseline), print tokens + steady tok/s as JSON."""
    from repro.launch.mesh import make_host_mesh

    m = args.mesh_child
    w = _mesh_workload(args)
    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, w["plen"])))
               for _ in range(w["batch"])]
    mesh = make_host_mesh(model_axis=m) if m > 1 else None
    max_len = _pow2_at_least(w["plen"] + w["new"] + w["page"])

    def make_engine():
        return ServeEngine(cfg, params, max_batch=w["batch"],
                           max_len=max_len, page_size=w["page"],
                           prefix_cache=False, mesh=mesh)

    eng = make_engine()
    uids = [eng.submit(list(p), max_new_tokens=w["new"]) for p in prompts]
    done = {r.uid: list(r.tokens)
            for r in eng.run_until_drained(max_steps=50_000)}
    tokens = [done[u] for u in uids]
    best = max(steady_decode_tps(eng, [list(p) for p in prompts], w["new"])
               for _ in range(args.passes))
    out = {"model_axis": m,
           "plan": eng._tp.plan if eng._tp is not None else "single",
           "devices": len(jax.devices()),
           "tok_s": best, "tokens": tokens,
           "decode_compiles": eng.decode_compiles}
    print(json.dumps(out))


def mesh_bench(args):
    """Tensor-parallel serving A/B (see module docstring): one subprocess
    per model_axis arm, token identity asserted across arms, rows written
    to BENCH_shard.json."""
    arms = [m for m in (1, 2, 4) if m <= args.mesh]
    if args.mesh not in arms:
        arms.append(args.mesh)
    ndev = max(arms)
    w = _mesh_workload(args)
    print(f"[serve-decode --mesh] arch={args.arch} attn={args.attn_impl} "
          f"batch={w['batch']} prompt={w['plen']} new={w['new']} "
          f"page={w['page']} arms={arms} "
          f"({ndev} forced host devices per arm)")
    rows = []
    for m in arms:
        cmd = [sys.executable, os.path.abspath(__file__),
               "--mesh-child", str(m), "--arch", args.arch,
               "--attn-impl", args.attn_impl, "--passes",
               str(args.passes)] + (["--tiny"] if args.tiny else [])
        env = dict(
            os.environ,
            XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev} "
                      + os.environ.get("XLA_FLAGS", ""),
            PYTHONPATH=os.pathsep.join(
                [os.path.join(os.path.dirname(os.path.dirname(
                    os.path.abspath(__file__))), "src")]
                + ([os.environ["PYTHONPATH"]]
                   if "PYTHONPATH" in os.environ else [])))
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1800)
        assert r.returncode == 0, r.stderr[-4000:]
        rows.append(json.loads(r.stdout.strip().splitlines()[-1]))
    base_tokens, base_tps = rows[0]["tokens"], rows[0]["tok_s"]
    for row in rows:
        assert row["tokens"] == base_tokens, \
            f"sharding changed the tokens at model_axis=" \
            f"{row['model_axis']} — mesh-serving bug"
        row.pop("tokens")
        row["speedup_vs_1"] = row["tok_s"] / base_tps
        print(f"  model_axis={row['model_axis']} plan={row['plan']:>9} "
              f"{row['tok_s']:>9.1f} tok/s "
              f"x{row['speedup_vs_1']:.2f} "
              f"({row['decode_compiles']} decode compiles)")
    top = rows[-1]["speedup_vs_1"]
    if args.tiny:
        if top < 1.5:
            print("  WARNING: tiny-scale numbers missed the 1.5x sharding "
                  "target (forced host devices timeshare one CPU; only "
                  "real accelerators show the win)")
    else:
        assert top >= 1.5, \
            f"model_axis {arms[-1]} must win >=1.5x over 1, got {top:.2f}x"
    out = {"bench": "serve_sharded", "arch": args.arch,
           "attn_impl": args.attn_impl, "tiny": bool(args.tiny),
           "workload": dict(w, devices=ndev), "arms": rows,
           "summary": {"speedup_max_axis_vs_1": top,
                       "tokens_identical": True}}
    with open("BENCH_shard.json", "w") as f:
        json.dump(out, f, indent=2)
    print("  wrote BENCH_shard.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--attn-impl", default="xla_flash",
                    choices=["tl_pallas", "xla_flash", "naive"])
    ap.add_argument("--sweep", action="store_true",
                    help="split-KV decode context-length sweep "
                         "(tok/s vs KV length, splits on/off)")
    ap.add_argument("--interleave", action="store_true",
                    help="SLO scheduler A/B: whole-prompt admission vs "
                         "budgeted chunked-prefill interleaving "
                         "(writes BENCH_serve.json)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decode A/B: draft length x "
                         "synthetic acceptance rate vs plain decode "
                         "(writes BENCH_spec.json)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="tensor-parallel serving A/B at model_axis "
                         "{1, 2, 4} clamped to N, one forced-host-device "
                         "subprocess per arm (writes BENCH_shard.json)")
    ap.add_argument("--mesh-child", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--passes", type=int, default=3,
                    help="warm passes per sweep cell (best-of filters "
                         "scheduler noise)")
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale smoke run for CI")
    args = ap.parse_args()
    if args.tiny:
        args.batch, args.prompt_len, args.new_tokens = 2, 12, 4
        args.passes = 1
    if args.mesh_child:
        mesh_child(args)
        return
    if args.mesh:
        mesh_bench(args)
        return
    if args.sweep:
        if args.tiny:
            args.new_tokens = 8
        sweep(args)
        return
    if args.interleave:
        interleave(args)
        return
    if args.spec:
        spec(args)
        return

    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          args.prompt_len)))
               for _ in range(args.batch)]
    n_tok = args.batch * args.new_tokens

    print(f"[serve-decode] arch={args.arch} attn={args.attn_impl} "
          f"batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    toks_l, compiles_l, dt_l = legacy_generate(cfg, params, prompts,
                                               args.new_tokens)
    print(f"  legacy (static cache_len): {compiles_l} decode compiles, "
          f"{dt_l:.2f}s cold, {n_tok / dt_l:.1f} tok/s incl. compiles")
    # warm pass is meaningless for legacy: every step recompiles anyway

    engine = ServeEngine(cfg, params, max_batch=args.batch, max_len=256)
    toks_b, compiles_b, dt_b = bucketed_generate(engine, prompts,
                                                 args.new_tokens)
    print(f"  bucketed (runtime cache_len): {compiles_b} decode compiles, "
          f"{dt_b:.2f}s cold, {n_tok / dt_b:.1f} tok/s incl. compiles")
    _, compiles_w, dt_w = bucketed_generate(engine, prompts, args.new_tokens)
    print(f"  bucketed warm (0 new compiles: "
          f"{compiles_w - compiles_b == 0}): "
          f"{dt_w:.2f}s, {n_tok / dt_w:.1f} tok/s steady-state")
    if not np.array_equal(toks_l, toks_b):
        print("  WARNING: token mismatch between regimes")
    print(f"  compile reduction: {compiles_l}x -> {compiles_b}x "
          f"per {args.new_tokens}-token generation")


if __name__ == "__main__":
    main()
