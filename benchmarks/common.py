"""Shared benchmark utilities.

This container has no GPU/TPU, so absolute TFLOPS are not measurable.
Each benchmark reports, per configuration:

  * CPU wall-clock (interpret/XLA-CPU) — for *relative* comparisons that
    mirror the paper's table layout (TL kernel vs naive vs reference), and
  * the analytic v5e projection from the autotuner's roofline model
    (``est_tflops``) — the number comparable to the paper's TFLOPS columns.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def paper_flops(seqlen: int, head_dim: int, heads: int, batch: int = 1,
                causal: bool = False) -> float:
    """The paper's convention: 4 * seqlen^2 * head_dim * heads."""
    f = 4.0 * seqlen * seqlen * head_dim * heads * batch
    return f / 2 if causal else f


class CsvOut:
    def __init__(self, header: list[str]):
        self.header = header
        print(",".join(header))

    def row(self, *vals):
        print(",".join(str(v) for v in vals))
