"""Paper Table 3/4 + Appendix B analogue: pipeline ablations.

* two-stage (sketch -> reason) vs one-stage TL generation: the one-stage
  backend manifests the paper's two failure modes; the validator's catch
  rate is the paper's "none of the existing LLMs generate correct TL code
  in a single stage" result, mechanised.
* development-cost table: TL pipeline wall-clock from spec to validated
  Pallas kernel (the paper's "10 mins vs months" row — here milliseconds,
  since the generator is deterministic).
"""

from __future__ import annotations

import time

from repro.core.llm import OneStageBackend
from repro.core.pipeline import generate_attention_kernel
from repro.core.spec import AttnSpec
from repro.core.target import get_target
from repro.core.tl.parser import parse
from repro.core.tl.validator import validate
from .common import CsvOut

SPECS = {
    "mha-128": AttnSpec.mha(16, 128),
    "gqa-128": AttnSpec.gqa(32, 8, 128),
    "mqa-64": AttnSpec.mqa(32, 64),
    "mla": AttnSpec.mla(16),
    "mha-window": AttnSpec.mha(16, 64, window=512),
}


def run():
    out = CsvOut(["spec", "mode", "valid", "caught_codes", "gen_ms"])
    for name, spec in SPECS.items():
        # two-stage (the paper's workflow)
        t0 = time.perf_counter()
        kern = generate_attention_kernel(spec, 1024, 1024)
        dt = (time.perf_counter() - t0) * 1e3
        errs = [d.code for d in kern.diagnostics if d.is_error]
        out.row(name, "two-stage", int(not errs), ";".join(errs) or "-",
                f"{dt:.1f}")
        # one-stage ablation: both Appendix-B failure modes
        for failure in ("reshape_omission", "gemm_layout_error"):
            backend = OneStageBackend(failure)
            t0 = time.perf_counter()
            txt = backend.generate_tl_code(spec, 1024, 1024,
                                           get_target("v5e"))
            prog = parse(txt)
            prog.meta["stage"] = "code"
            prog.outputs = ("O",)
            from repro.core.reason import reason_parameters
            from repro.core.sketch import generate_sketch
            prog.params = reason_parameters(
                generate_sketch(spec), spec, q_len=1024, kv_len=1024).params
            codes = sorted({d.code for d in validate(prog) if d.is_error})
            dt = (time.perf_counter() - t0) * 1e3
            out.row(name, f"one-stage/{failure}", 0, ";".join(codes),
                    f"{dt:.1f}")


if __name__ == "__main__":
    run()
