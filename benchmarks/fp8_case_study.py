"""Paper Table 6 analogue: datatype portability (FP8 MHA).

The paper's case study generates an FP8 MHA kernel for L40S — a dtype no
hand library supported — by swapping the hardware description in the
translation prompt.  Here the same portability lever is the
:class:`TPUTarget` descriptor: describe a v6e-class part (fp8-capable MXU,
2x bf16 throughput) and the *same TL pipeline* re-reasons block sizes and
re-projects the roofline; the kernel itself is validated in interpret mode
at bf16 numerics (no fp8 hardware here — documented in DESIGN.md A4).
"""

from __future__ import annotations


from repro.core import autotune
from repro.core.pipeline import generate_attention_kernel
from repro.core.reason import _vmem_bytes
from repro.core.spec import AttnSpec
from repro.core.target import get_target
from .common import CsvOut


def run():
    out = CsvOut(["seqlen", "dtype", "target", "BM", "BN", "onchip_kb",
                  "est_tflops", "valid"])
    v6e = get_target("v6e")
    peak_fp8 = v6e.peak_bf16_tflops * 2  # fp8 MXU rate on v6e-class parts
    for s in (512, 1024, 2048, 4096, 8192, 16384):
        for dtype, tgt, peak in (
                ("bf16", "v5e", get_target("v5e").peak_bf16_tflops),
                                 ("bf16", "v6e", v6e.peak_bf16_tflops),
                                 ("fp8", "v6e", peak_fp8)):
            spec = AttnSpec.mha(16, 128, dtype=dtype)
            kern = generate_attention_kernel(spec, s, s, target=tgt)
            tune = autotune.tune(spec, s, s, tgt)
            onchip = _vmem_bytes(spec, tune.blocks.bm, tune.blocks.bn)
            est = tune.efficiency * peak
            errs = [d for d in kern.diagnostics if d.is_error]
            out.row(s, dtype, tgt, tune.blocks.bm, tune.blocks.bn,
                    f"{onchip/1024:.0f}", f"{est:.1f}", int(not errs))


if __name__ == "__main__":
    run()
