"""Paper Table 1 analogue: MHA/GQA/MQA x seqlen x causal.

Columns:
  naive_ms     — materialised-scores einsum attention (the "vanilla LLM"
                 implementation; what DeepSeek-V3 produced in the paper)
  tl_ms        — the TL-generated kernel (Pallas interpret on CPU)
  xla_flash_ms — the same TL blocking through XLA (the model compile path)
  est_v5e_tflops — autotuner roofline projection for the TL kernel on v5e
  paper convention FLOPs: 4*s^2*d*h (halved for causal)

Sequence lengths are scaled down from the paper's 512..16k to keep CPU
runtime sane; pass --full for the paper grid.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import autotune
from repro.core.spec import AttnSpec
from repro.kernels import ops, ref
from .common import CsvOut, paper_flops, timeit


def run(full: bool = False):
    seqlens = [512, 1024, 2048, 4096, 8192, 16384] if full else [256, 512, 1024]
    total_tokens = 16384 if full else 2048  # paper: batch*seq = 16k
    out = CsvOut(["variant", "causal", "seqlen", "head_dim", "naive_ms",
                  "tl_ms", "xla_flash_ms", "est_v5e_tflops",
                  "paper_gflops"])
    rng = np.random.default_rng(0)
    for head_dim, heads in [(64, 16), (128, 8)] if not full else [(64, 32), (128, 16)]:
        for variant, kvh in [("mha", heads), ("gqa", max(1, heads // 4)),
                             ("mqa", 1)]:
            for causal in (True, False):
                for s in seqlens:
                    b = max(1, total_tokens // s)
                    q = jnp.asarray(rng.standard_normal(
                        (b, heads, s, head_dim)) * 0.5, jnp.float32)
                    k = jnp.asarray(rng.standard_normal(
                        (b, kvh, s, head_dim)) * 0.5, jnp.float32)
                    v = jnp.asarray(rng.standard_normal(
                        (b, kvh, s, head_dim)) * 0.5, jnp.float32)

                    t_naive = timeit(lambda: ref.attention(
                        q, k, v, causal=causal))
                    t_tl = timeit(lambda: ops.flash_attention(
                        q, k, v, causal=causal))
                    from repro.models.attention import xla_flash
                    t_xla = timeit(lambda: xla_flash(
                        q, k, v, causal=causal, scale=head_dim ** -0.5,
                        chunk=512))
                    spec = AttnSpec(variant=variant, num_q_heads=heads,
                                    num_kv_heads=kvh, head_dim=head_dim,
                                    causal=causal)
                    tune = autotune.tune(spec, s, s, "v5e")
                    est = tune.efficiency * 197.0
                    out.row(variant, int(causal), s, head_dim,
                            f"{t_naive*1e3:.1f}", f"{t_tl*1e3:.1f}",
                            f"{t_xla*1e3:.1f}", f"{est:.1f}",
                            f"{paper_flops(s, head_dim, heads, b, causal)/1e9:.1f}")


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
