"""TL derivations for every attention family + the Appendix-B ablation.

Prints the full sketch -> TL-code derivation for MHA, GQA, MQA, MLA and a
sliding-window variant, then demonstrates the validator catching both
one-stage failure modes (reshape omission, GEMM layout error).

    PYTHONPATH=src python examples/tl_showcase.py
"""

from repro.core import AttnSpec
from repro.core.llm import OneStageBackend
from repro.core.pipeline import generate_attention_kernel
from repro.core.target import get_target
from repro.core.tl.parser import parse
from repro.core.tl.validator import validate

SPECS = {
    "MHA (GPT-style)": AttnSpec.mha(32, 128),
    "GQA (llama-3 style)": AttnSpec.gqa(32, 8, 128),
    "MQA (falcon-style)": AttnSpec.mqa(32, 64),
    "MLA (DeepSeek-V3)": AttnSpec.mla(128),
    "sliding-window": AttnSpec.mha(16, 64, window=1024),
}


def main():
    for name, spec in SPECS.items():
        kern = generate_attention_kernel(spec, 4096, 4096)
        print(f"\n{'='*70}\n{name}: BM={kern.blocks.bm} BN={kern.blocks.bn} "
              f"(est {kern.tune.efficiency*197:.0f} TFLOP/s on v5e)")
        print(kern.tl_text)

    print(f"\n{'='*70}\nAppendix-B ablation: one-stage generation")
    for failure in ("reshape_omission", "gemm_layout_error"):
        txt = OneStageBackend(failure).generate_tl_code(
            AttnSpec.mha(16, 128), 4096, 4096, get_target("v5e"))
        prog = parse(txt)
        prog.meta["stage"] = "code"
        prog.outputs = ("O",)
        from repro.core.reason import reason_parameters
        from repro.core.sketch import generate_sketch
        spec = AttnSpec.mha(16, 128)
        prog.params = reason_parameters(generate_sketch(spec), spec,
                                        q_len=4096, kv_len=4096).params
        errs = [d for d in validate(prog) if d.is_error]
        print(f"\n--- {failure}: validator says ---")
        for d in errs:
            print(f"  {d}")


if __name__ == "__main__":
    main()
