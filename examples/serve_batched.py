"""Batched serving example: prefill + KV-cache decode through the
TL-generated runtime-length attention kernels.

Demonstrates the bucketed serving contract:

  * prompt lengths in one batch may *differ* (right-padded prefill,
    per-request last-position gather, per-request cache-length masking);
  * decode compiles once per power-of-two length bucket — the example
    prints the compile counters so you can see generation length not
    showing up in them;
  * the ``submit``/``step`` continuous-batching API admits and retires
    requests between decode steps.

    PYTHONPATH=src python examples/serve_batched.py --arch deepseek-v2-lite-16b
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--attn-impl", default="tl_pallas",
                    choices=["tl_pallas", "xla_flash", "naive"])
    args = ap.parse_args()

    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    vision = None
    if cfg.cross_attn_period:
        vision = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.num_patches, cfg.vision_d))
    engine = ServeEngine(cfg, params, max_batch=args.batch, max_len=256,
                         vision_embeds=vision)

    # heterogeneous prompt lengths (recurrent archs need them homogeneous
    # in batched generate; the step API below handles mixed lengths there)
    rng = np.random.default_rng(0)
    lens = [max(1, args.prompt_len - 4 * i) for i in range(args.batch)]
    if engine.recurrent or vision is not None:
        lens = [args.prompt_len] * args.batch
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in lens]
    t0 = time.time()
    res = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"[serve] arch={args.arch} attn={args.attn_impl} "
          f"{args.batch} seqs (lens {lens}) x {args.new_tokens} tokens "
          f"in {dt:.2f}s")
    print(f"[serve] compiles: prefill={engine.prefill_compiles} "
          f"decode={engine.decode_compiles} "
          f"(buckets, not steps — {args.new_tokens} tokens decoded)")
    for i, row in enumerate(res.tokens):
        print(f"  seq{i} (prompt {res.prompt_len[i]}): {row.tolist()}")

    # continuous batching: requests enter and leave between decode steps
    if vision is None:
        engine2 = ServeEngine(cfg, params, max_batch=2, max_len=256)
        for n, new in ((8, 6), (14, 3), (5, 4)):   # 3 requests, 2 slots
            engine2.submit(list(map(int, rng.integers(0, cfg.vocab_size, n))),
                           max_new_tokens=new)
        t0 = time.time()
        done = engine2.run_until_drained()
        print(f"[serve] step API drained {len(done)} requests through 2 "
              f"slots in {time.time() - t0:.2f}s; "
              f"decode compiles={engine2.decode_compiles}")
        for r in sorted(done, key=lambda r: r.uid):
            print(f"  req{r.uid} (prompt {len(r.prompt)}): {r.tokens}")


if __name__ == "__main__":
    main()
