"""Batched serving example: prefill + KV-cache decode through the
TL-generated attention kernels.

    PYTHONPATH=src python examples/serve_batched.py --arch deepseek-v2-lite-16b
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.models import registry
from repro.models import transformer as T
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--attn-impl", default="tl_pallas",
                    choices=["tl_pallas", "xla_flash", "naive"])
    args = ap.parse_args()

    cfg = dataclasses.replace(registry.get_reduced(args.arch),
                              attn_impl=args.attn_impl)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    vision = None
    if cfg.cross_attn_period:
        vision = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.num_patches, cfg.vision_d))
    engine = ServeEngine(cfg, params, max_batch=args.batch, max_len=256,
                         vision_embeds=vision)

    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          args.prompt_len)))
               for _ in range(args.batch)]
    t0 = time.time()
    res = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"[serve] arch={args.arch} attn={args.attn_impl} "
          f"{args.batch} seqs x {args.new_tokens} tokens in {dt:.2f}s")
    for i, row in enumerate(res.tokens):
        print(f"  seq{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
