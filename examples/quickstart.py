"""Quickstart: generate a FlashAttention kernel through the TL workflow.

Shows the paper's Figure 3 pipeline end-to-end: user requirement (an
AttnSpec) -> TL Sketch -> parameter reasoning -> validated TL Code ->
Pallas kernel, then runs the kernel against the reference.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import AttnSpec, generate_attention_kernel
from repro.kernels import ref


def main():
    # 1. the "user requirement": GQA, 32 q heads / 8 kv heads, causal
    spec = AttnSpec.gqa(32, 8, head_dim=128, causal=True, dtype="f32")
    print(f"spec: {spec}\n")

    # 2. run the 2-stage workflow (sketch -> reason -> validate -> translate)
    kern = generate_attention_kernel(spec, q_len=1024, kv_len=1024)

    print("=== Stage 1a: TL Sketch (semantic execution flow) ===")
    print(kern.sketch_text)
    print("=== Stage 1b: TL Code (parameters reasoned; note the Reshape) ===")
    print(kern.tl_text)
    print(f"autotuned blocks: BM={kern.blocks.bm} BN={kern.blocks.bn}; "
          f"validation: {len([d for d in kern.diagnostics if d.is_error])} "
          f"errors, {len(kern.diagnostics)} diagnostics")
    if kern.tune:
        print(f"roofline projection on v5e: "
              f"{kern.tune.efficiency * 197:.0f} TFLOP/s "
              f"({kern.tune.candidates_tried} candidates searched)\n")

    # 3. run it (interpret mode on CPU; Mosaic on a real TPU)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 32, 1024, 128)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 1024, 128)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, 1024, 128)) * 0.5, jnp.float32)
    out = kern.pallas_fn(q, k, v)
    gold = ref.attention(q, k, v, causal=True)
    err = float(jnp.abs(out.astype(jnp.float32) - gold).max())
    print(f"kernel vs reference max|err| = {err:.2e}")
    assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
