"""End-to-end driver: train a ~100M-param llama-style model on the
synthetic pipeline with checkpointing and fault tolerance.

    PYTHONPATH=src python examples/train_100m.py --steps 200

The config is a scaled deepseek-7b family member (~103M params).  On this
CPU container ~200 steps of batch 8 x seq 256 takes a while; pass smaller
--steps for a smoke run.  Loss drops from ~ln(V) toward the entropy of the
synthetic Markov stream — the curve is printed at the end.
"""

import argparse

from repro.launch.train import run
from repro.models.config import ModelConfig
from repro.models import registry


CFG_100M = ModelConfig(
    name="llama-100m", family="dense",
    num_layers=8, d_model=512, num_q_heads=8, num_kv_heads=4,
    d_ff=2048, vocab_size=32768, head_dim=64, dtype="f32",
    rope_theta=10000.0,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # register the config on the fly so launch.train can find it
    registry._MODULES["llama-100m"] = "deepseek_7b"  # module for reduced()
    import repro.configs.deepseek_7b as m
    orig = m.CONFIG
    m.CONFIG = CFG_100M
    try:
        n = CFG_100M.param_count()
        print(f"[example] llama-100m: {n/1e6:.1f}M params")
        losses = run("llama-100m", reduced=False, steps=args.steps,
                     batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, ckpt_every=50, lr=1e-3,
                     log_every=10)
    finally:
        m.CONFIG = orig
    k = max(1, len(losses) // 10)
    smooth = [sum(losses[i:i + k]) / len(losses[i:i + k])
              for i in range(0, len(losses), k)]
    print("[example] smoothed loss curve:",
          " -> ".join(f"{l:.3f}" for l in smooth))
    assert losses[-1] < losses[0]
    print("[example] OK — loss decreased")


if __name__ == "__main__":
    main()
