"""AdamW + schedules, hand-rolled (no optax in this container).

Optimizer state dtype is configurable: f32 moments by default; ``bf16``
halves optimizer HBM for >=100B-param archs (DESIGN.md §3.1) at the cost of
stochastic-rounding-free moment noise (acceptable with f32 master compute
here: moments are upcast before use).
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "f32"         # f32 | bf16
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bf16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cosine_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    sdt = jnp.bfloat16 if cfg.state_dtype == "bf16" else jnp.float32

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
