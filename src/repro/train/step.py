"""Train step: microbatched gradient accumulation + AdamW, jit/pjit-ready.

The global batch is split into ``grad_accum`` microbatches scanned *inside*
the step (the activation-memory lever at scale, DESIGN.md §3.1).  Gradients
accumulate in f32.  NaN/inf grads are detected and reported in metrics so
the supervisor loop (launch/train.py) can trigger restore-and-skip — the
fault-tolerance path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step), None),
    lambda aux, ch: TrainState(*ch))


def train_state_init(key, cfg: ModelConfig, opt_cfg: AdamWConfig):
    params = transformer.init_params(key, cfg)
    return TrainState(params=params, opt_state=adamw_init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def abstract_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """ShapeDtypeStruct TrainState for dry-runs (no allocation)."""
    params = transformer.abstract_params(cfg)
    opt = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg), params)
    return TrainState(params=params, opt_state=opt,
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_accum: int = 1, act_sharding=None,
                    grad_sharding=None, ep_sharding=None,
                    head_sharding=None, latent_sharding=None,
                    moe_mesh=None) -> Callable:
    """Returns ``step(state, batch) -> (state, metrics)``.

    batch: {"tokens": (B, S), "labels": (B, S), ["vision_embeds": ...]}
    with B divisible by ``grad_accum``.
    """

    def loss(params, micro):
        vision = micro.get("vision_embeds")
        total, parts = transformer.loss_fn(params, micro, cfg,
                                           vision_embeds=vision,
                                           act_sharding=act_sharding,
                                           ep_sharding=ep_sharding,
                                           head_sharding=head_sharding,
                                           latent_sharding=latent_sharding,
                                           moe_mesh=moe_mesh)
        return total, parts

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step(state: TrainState, batch: dict):
        b = batch["tokens"].shape[0]
        mb = b // grad_accum

        def micro_slices(i):
            return {k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, 0)
                    for k, v in batch.items()}

        def gconstrain(tree):
            # keep the f32 accumulation carry sharded like the params —
            # without this GSPMD replicates the carry and all-gathers /
            # all-reduces FULL weight gradients once per period*microbatch
            # (a 10x collective blow-up measured on llama3-405b, see
            # EXPERIMENTS.md §Perf)
            if grad_sharding is None:
                return tree
            return jax.tree.map(jax.lax.with_sharding_constraint,
                                tree, grad_sharding)

        def accum(carry, i):
            gacc, lacc = carry
            (l, parts), g = grad_fn(state.params, micro_slices(i))
            g32 = jax.tree.map(lambda a, acc: acc + a.astype(jnp.float32),
                               g, gacc)
            return (gconstrain(g32), lacc + l), parts

        zeros = gconstrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
        if grad_accum == 1:
            (l, parts), grads = grad_fn(state.params, batch)
            loss_val = l
        else:
            (grads, loss_sum), parts = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(grad_accum))
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss_val = loss_sum / grad_accum
            parts = jax.tree.map(lambda x: x[-1], parts)

        finite = jnp.all(jnp.asarray(
            [jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)]))
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt_state, state.params, opt_cfg)
        # fault tolerance: skip the update when grads are non-finite
        new_params = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_params, state.params)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1)
        metrics = {"loss": loss_val, "finite": finite, **opt_metrics,
                   **parts}
        return new_state, metrics

    return step
