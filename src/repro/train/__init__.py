from .optimizer import adamw_init, adamw_update, cosine_schedule  # noqa: F401
from .step import TrainState, make_train_step, train_state_init  # noqa: F401
