"""Sharded, fault-tolerant checkpointing.

Layout (one directory per step)::

    <root>/step_000123.tmp-<nonce>/     # written here first
        manifest.json                   # treedef, shapes, dtypes, hashes
        leaf_00000.npy ...              # one file per pytree leaf
    <root>/step_000123/                 # atomic rename on commit

Properties required at 1000-node scale (DESIGN.md §3.1):

* **atomic commit** — a step directory either exists completely or not at
  all (rename is atomic); a crashed writer leaves only ``.tmp-*`` litter
  that GC removes.
* **integrity** — every leaf carries a content hash in the manifest;
  restore verifies before use.
* **restore-with-reshard** — leaves are saved *unsharded* (gathered); the
  restorer device_puts onto whatever sharding the new mesh prescribes, so a
  job may restart on a different mesh shape (elastic scaling).  At real
  multi-host scale each host would write only its address-span slices; the
  single-process container writes full arrays, same layout.
* **keep-last-k GC** + async save off the training thread.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import uuid
from typing import Any, Optional

import jax
import numpy as np


def _leaf_hash(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def save(root: str, step: int, tree: Any, *, extra: Optional[dict] = None) -> str:
    """Write checkpoint for ``step``; returns the committed path."""
    os.makedirs(root, exist_ok=True)
    final = _step_dir(root, step)
    tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    try:
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "hash": _leaf_hash(arr),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):          # idempotent re-save
            shutil.rmtree(final)
        os.rename(tmp, final)              # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(root, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(root: str, step: int, like: Any, *,
            shardings: Any = None, verify: bool = True) -> Any:
    """Restore ``step`` into the structure of ``like`` (a pytree of arrays
    or ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for restore-with-reshard."""
    path = _step_dir(root, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    like_leaves, treedef = jax.tree.flatten(like)
    if manifest["num_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, expected "
            f"{len(like_leaves)} — structure mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0]
                    if shardings is not None else [None] * len(like_leaves))
    out = []
    for i, (meta, like_leaf, shard) in enumerate(
            zip(manifest["leaves"], like_leaves, shard_leaves)):
        arr = np.load(os.path.join(path, meta["file"]))
        if verify and _leaf_hash(arr) != meta["hash"]:
            raise IOError(f"hash mismatch in {meta['file']} — corrupt "
                          f"checkpoint {path}")
        if tuple(arr.shape) != tuple(like_leaf.shape):
            raise ValueError(
                f"leaf {i} shape {arr.shape} != expected {like_leaf.shape}")
        if shard is not None:
            out.append(jax.device_put(arr.astype(like_leaf.dtype), shard))
        else:
            out.append(jax.numpy.asarray(arr.astype(like_leaf.dtype)))
    return treedef.unflatten(out)


def gc_keep_last(root: str, keep: int) -> list[str]:
    """Remove all but the newest ``keep`` committed steps + tmp litter."""
    removed = []
    if not os.path.isdir(root):
        return removed
    for name in os.listdir(root):
        if ".tmp-" in name:
            shutil.rmtree(os.path.join(root, name), ignore_errors=True)
            removed.append(name)
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(root)
        if n.startswith("step_") and ".tmp" not in n)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)
        removed.append(f"step_{s:09d}")
    return removed


class CheckpointManager:
    """Async save + keep-last-k GC + restore-latest."""

    def __init__(self, root: str, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # materialise on host *before* handing to the writer thread so the
        # training loop can mutate device buffers immediately
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.root, step, host_tree, extra=extra)
                gc_keep_last(self.root, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore_latest(self, like: Any, shardings: Any = None):
        step = latest_step(self.root)
        if step is None:
            return None, None
        return step, restore(self.root, step, like, shardings=shardings)
