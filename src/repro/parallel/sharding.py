"""Sharding rules: logical parameter/activation layout -> PartitionSpec.

Axes (launch/mesh.py): ``('data','model')`` single-pod 16x16,
``('pod','data','model')`` multi-pod 2x16x16.  The data-parallel group is
``('pod','data')`` when the pod axis exists — FSDP shards cross pods, so a
parameter all-gather crosses the ICI/DCI boundary once per layer while the
gradient reduce-scatter overlaps the backward walk.

Policy (Megatron/MaxText-style):

* TP ('model') on the head/ff/expert/vocab dim — column-parallel in,
  row-parallel out, one all-reduce per block.
* FSDP (DP axes) on the other large dim of every weight (ZeRO-3).
* Dims that don't divide their axis fall back (try the other dim, then
  replicate) — configs like 56-head coder or kv=4 Qwen stay valid on a
  16-wide model axis.

Every rule is expressed on the *base* (unstacked) shape; leading scan/stack
dims (periods) are automatically skipped.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

DP_AXES = ("pod", "data")   # FSDP group (pod axis present only multi-pod)


def _dp(mesh: Mesh):
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


# rule table: last-path-key -> per-dim axis *preference* on the base shape.
# 'M' = model (TP), 'D' = data/FSDP, None = replicated.
_RULES: dict[str, tuple] = {
    # embeddings / head
    "table": ("M", "D"),                 # (V, d)
    "lm_head": ("D", "M"),               # (d, V)
    # attention
    "wq": ("D", "M", None),              # (d, H, hd)
    "wk": ("D", "M", None),
    "wv": ("D", "M", None),
    "wo": ("M", None, "D"),              # (H, hd, d)
    # MLA
    "w_dkv": ("D", None),                # (d, R+rr)
    "w_dq": ("D", None),
    "w_uq": ("D", "M", None),            # (qr, H, nope+rr)
    "w_q": ("D", "M", None),
    "w_uk": ("D", "M", None),            # (R, H, nope)
    "w_uv": ("D", "M", None),            # (R, H, vd)
    "w_o": ("M", None, "D"),             # (H, vd, d)
    # dense FFN
    "w_gate": ("D", "M"),                # (d, ff)
    "w_up": ("D", "M"),
    "w_down": ("M", "D"),                # (ff, d)
    # MoE experts (E, d, ff)/(E, ff, d): expert-parallel on E, FSDP on d
    "we_gate": ("M", "D", None),
    "we_up": ("M", "D", None),
    "we_down": ("M", None, "D"),
    "router": ("D", None),               # (d, E)
    # mamba
    "w_in": ("D", "M"),                  # (d, 2di)
    "conv": (None, "M"),                 # (kw, di)
    "w_x_dbc": ("M", None),              # (di, r+2s)
    "w_dt": (None, "M"),                 # (r, di)
    "dt_bias": ("M",),
    "A_log": ("M", None),                # (di, S)
    "D": ("M",),
    "w_out": ("M", "D"),                 # (di, d)
    # rwkv
    "w_r": ("D", "M"), "w_k": ("D", "M"), "w_v": ("D", "M"),
    "w_g": ("D", "M"),
    "decay_A": ("D", None), "decay_B": (None, "M"),
    "u": ("M", None),                    # (h, hd)
    "cm_k": ("D", "M"), "cm_v": ("M", "D"), "cm_r": ("D", "M"),
}

def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.SequenceKey):
            continue
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def param_pspec(path, leaf, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (divisibility-checked)."""
    name = _leaf_name(path)
    shape = leaf.shape
    rule = _RULES.get(name)
    if rule is None:
        return P()  # norms, biases, mu, ... replicated
    base = len(rule)
    lead = len(shape) - base
    if lead < 0:
        return P()
    dp = _dp(mesh)
    axis_of = {"M": "model", "D": dp}

    def flat(ax) -> set:
        return set(ax) if isinstance(ax, tuple) else {ax}

    spec: list = [None] * len(shape)
    used: set = set()
    for i, want in enumerate(rule):
        if want is None:
            continue
        dim = lead + i
        for ax in (axis_of[want], axis_of["D" if want == "M" else "M"]):
            if ax is None or flat(ax) & used:
                continue
            if shape[dim] % _axis_size(mesh, ax) == 0 and \
                    shape[dim] >= _axis_size(mesh, ax) and shape[dim] > 1:
                spec[dim] = ax
                used |= flat(ax)
                break
    return P(*spec)


def param_sharding_tree(abstract_params, mesh: Mesh):
    """NamedSharding pytree matching ``abstract_params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh)),
        abstract_params)


def batch_pspec(mesh: Mesh, *, seq_shard: bool = False) -> P:
    """(B, S) token batches: batch over the DP group; optionally sequence
    over 'model' (sequence parallelism for very long prefill)."""
    dp = _dp(mesh)
    return P(dp, "model" if seq_shard else None)


def activation_pspec(mesh: Mesh) -> P:
    dp = _dp(mesh)
    return P(dp, None, None)


def cache_pspec(path, leaf, mesh: Mesh, *, batch: int,
                shard_seq_when_small_batch: bool = True) -> P:
    """Decode caches.  Normal case: batch over DP, heads over model.
    long-context batch=1: heads rarely divide — shard the *sequence* dim
    over 'model' instead (each shard holds a KV stripe; the online-softmax
    combine is a small cross-shard reduction)."""
    name = _leaf_name(path)
    shape = leaf.shape
    dp = _dp(mesh)
    dp_size = _axis_size(mesh, dp)
    spec: list = [None] * len(shape)
    if name in ("k", "v"):            # (periods?, B, Hkv, N, hd)
        b_dim = len(shape) - 4
        h_dim, n_dim = b_dim + 1, b_dim + 2
        if batch % dp_size == 0 and batch > 1:
            spec[b_dim] = dp
        if shape[h_dim] % mesh.shape["model"] == 0:
            spec[h_dim] = "model"
        elif shard_seq_when_small_batch and \
                shape[n_dim] % mesh.shape["model"] == 0:
            spec[n_dim] = "model"
    elif name == "c":                  # MLA latent (periods?, B, N, R+rr)
        b_dim = len(shape) - 3
        if batch % dp_size == 0 and batch > 1:
            spec[b_dim] = dp
        if shape[b_dim + 1] % mesh.shape["model"] == 0:
            spec[b_dim + 1] = "model"
    elif name in ("h", "S", "conv", "shift"):  # ssm/rwkv states
        b_dim = len(shape) - (3 if name in ("h", "conv") else
                              4 if name == "S" else 2)
        if batch % dp_size == 0 and batch > 1:
            spec[b_dim] = dp
        # d_inner / heads over model where divisible
        for dim in range(b_dim + 1, len(shape)):
            if spec[dim] is None and shape[dim] % mesh.shape["model"] == 0 \
                    and shape[dim] >= mesh.shape["model"]:
                spec[dim] = "model"
                break
    return P(*spec)


def named_sharding_tree(abstract_tree, mesh: Mesh, pspec_fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, pspec_fn(path, leaf, mesh)),
        abstract_tree)


# --------------------------------------------------------------------------
# tensor-parallel serving (head-sharded paged attention under shard_map)
# --------------------------------------------------------------------------
#
# The serving engine runs its whole hot path (decode / chunk prefill /
# verify) inside shard_map over a ('data', 'model') mesh.  Unlike the
# training rules above (GSPMD annotations), serving shards *explicitly*:
# every shard executes the same program on its slice, and the only
# cross-shard communication is one psum per block (head plans) or one
# LSE-merge all-gather per attention (MLA's sequence plan).  Host-side
# state — the page allocator, block tables, scale tables, prefix index —
# stays replicated and byte-identical: one allocator, one admission
# decision, N shards.
#
# Plan ladder (``choose_serve_plan``):
#   'kv'        GQA/MQA, Hkv % mp == 0: shard KV heads (and their whole
#               query groups) contiguously; page pools shard on their head
#               axis, so each shard's pool is the head slice of the
#               single-device pool.
#   'q'         Hkv doesn't divide but Hq and the group size do: KV stays
#               replicated, query heads shard after a group-interleaved
#               permutation (``q_head_permutation``) so each shard's
#               contiguous head slice still reshapes to (Hkv, G/mp).
#   'seq'       MLA (one latent KV head): pool/tables/params replicated,
#               each rank attends over its slice of the table columns and
#               the online-softmax states LSE-merge across the axis.
#   'replicate' fallback — every shard does the full computation (also
#               forced for padded-head configs, whose pad masking is not
#               slice-invariant).

@dataclasses.dataclass(frozen=True)
class ServeTP:
    """Tensor-parallel serving context, threaded into ``transformer.apply``
    (inside shard_map) so sub-layers know which axis to reduce over."""
    axis: str = "model"
    size: int = 1
    plan: str = "replicate"      # 'kv' | 'q' | 'seq' | 'replicate'
    ffn: bool = False            # dense-FFN w_down contraction is sharded


def choose_serve_plan(cfg: ModelConfig, model_axis: int,
                      axis: str = "model") -> ServeTP:
    """Pick the head-sharding plan for serving ``cfg`` over ``model_axis``
    shards (the fallback ladder above)."""
    mp = max(1, int(model_axis))
    ffn = (mp > 1 and not cfg.rwkv and cfg.d_ff % mp == 0)
    if mp == 1:
        return ServeTP(axis=axis, size=1, plan="replicate", ffn=False)
    if cfg.rwkv or cfg.hybrid_period or cfg.cross_attn_period:
        # non-attention mixers keep their own state layouts — replicate
        return ServeTP(axis=axis, size=mp, plan="replicate", ffn=ffn)
    if cfg.pad_q_heads_to > cfg.num_q_heads:
        return ServeTP(axis=axis, size=mp, plan="replicate", ffn=ffn)
    if cfg.mla:
        # power-of-two axis keeps every power-of-two KV bucket divisible
        plan = "seq" if mp & (mp - 1) == 0 else "replicate"
        return ServeTP(axis=axis, size=mp, plan=plan, ffn=ffn)
    hq, hkv = cfg.num_q_heads, cfg.num_kv_heads
    if hkv % mp == 0:
        return ServeTP(axis=axis, size=mp, plan="kv", ffn=ffn)
    if hq % mp == 0 and (hq // hkv) % mp == 0:
        return ServeTP(axis=axis, size=mp, plan="q", ffn=ffn)
    return ServeTP(axis=axis, size=mp, plan="replicate", ffn=ffn)


def q_head_permutation(cfg: ModelConfig, mp: int) -> list[int]:
    """Group-interleaved query-head order for the 'q' plan.

    Contiguous head slices break GQA's grouped reshape when Hkv stays
    replicated; reordering heads so shard ``s`` holds, for every KV head,
    the ``s``-th sub-group of its queries restores it: the local head
    index ``kv * gl + j`` maps to KV head ``idx // gl`` exactly like the
    unsharded layout.  (Identity for MQA, where Hkv == 1.)"""
    hq, hkv = cfg.num_q_heads, cfg.num_kv_heads
    g = hq // hkv
    gl = g // mp
    return [kv * g + s * gl + j
            for s in range(mp) for kv in range(hkv) for j in range(gl)]


def permute_q_heads(params, cfg: ModelConfig, mp: int):
    """Apply :func:`q_head_permutation` to every wq (head axis -2) and wo
    (head axis -3) leaf — done once, host-side, before placing the params
    on the mesh under the 'q' plan."""
    import jax.numpy as jnp
    perm = jnp.asarray(q_head_permutation(cfg, mp))

    def fix(path, leaf):
        name = _leaf_name(path)
        if name == "wq":
            return jnp.take(leaf, perm, axis=leaf.ndim - 2)
        if name == "wo":
            return jnp.take(leaf, perm, axis=leaf.ndim - 3)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, params)


# serving rules: leaf name -> per-dim spec on the *base* (unstacked) shape;
# leading period/stack dims replicate.  Only the model axis is used — data
# parallelism in serving is request routing, not tensor slicing.
_SERVE_RULES: dict[str, dict[str, tuple]] = {
    "kv": {
        "wq": (None, "model", None), "wk": (None, "model", None),
        "wv": (None, "model", None), "wo": ("model", None, None),
    },
    "q": {
        "wq": (None, "model", None), "wo": ("model", None, None),
    },
}
_SERVE_FFN_RULES: dict[str, tuple] = {
    "w_gate": (None, "model"), "w_up": (None, "model"),
    "w_down": ("model", None),
}


def serve_param_pspec(path, leaf, tp: ServeTP) -> P:
    """PartitionSpec for one parameter leaf under a serving plan."""
    if tp.size <= 1:
        return P()
    name = _leaf_name(path)
    rule = _SERVE_RULES.get(tp.plan, {}).get(name)
    if rule is None and tp.ffn:
        rule = _SERVE_FFN_RULES.get(name)
    if rule is None:
        return P()
    lead = len(leaf.shape) - len(rule)
    if lead < 0:
        return P()
    return P(*([None] * lead + [a for a in rule]))


def serve_cache_pspec(path, leaf, tp: ServeTP) -> P:
    """PartitionSpec for one paged-cache leaf under a serving plan.

    Only the 'kv' plan shards device state: the k/v page pools split on
    their head axis (ndim-3).  Scale leaves, MLA latent pools and every
    recurrent state stay replicated."""
    name = _leaf_name(path)
    if tp.size > 1 and tp.plan == "kv" and name in ("k", "v") \
            and len(leaf.shape) >= 4:
        spec: list = [None] * len(leaf.shape)
        spec[len(leaf.shape) - 3] = "model"
        return P(*spec)
    return P()
