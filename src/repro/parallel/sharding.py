"""Sharding rules: logical parameter/activation layout -> PartitionSpec.

Axes (launch/mesh.py): ``('data','model')`` single-pod 16x16,
``('pod','data','model')`` multi-pod 2x16x16.  The data-parallel group is
``('pod','data')`` when the pod axis exists — FSDP shards cross pods, so a
parameter all-gather crosses the ICI/DCI boundary once per layer while the
gradient reduce-scatter overlaps the backward walk.

Policy (Megatron/MaxText-style):

* TP ('model') on the head/ff/expert/vocab dim — column-parallel in,
  row-parallel out, one all-reduce per block.
* FSDP (DP axes) on the other large dim of every weight (ZeRO-3).
* Dims that don't divide their axis fall back (try the other dim, then
  replicate) — configs like 56-head coder or kv=4 Qwen stay valid on a
  16-wide model axis.

Every rule is expressed on the *base* (unstacked) shape; leading scan/stack
dims (periods) are automatically skipped.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP_AXES = ("pod", "data")   # FSDP group (pod axis present only multi-pod)


def _dp(mesh: Mesh):
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


# rule table: last-path-key -> per-dim axis *preference* on the base shape.
# 'M' = model (TP), 'D' = data/FSDP, None = replicated.
_RULES: dict[str, tuple] = {
    # embeddings / head
    "table": ("M", "D"),                 # (V, d)
    "lm_head": ("D", "M"),               # (d, V)
    # attention
    "wq": ("D", "M", None),              # (d, H, hd)
    "wk": ("D", "M", None),
    "wv": ("D", "M", None),
    "wo": ("M", None, "D"),              # (H, hd, d)
    # MLA
    "w_dkv": ("D", None),                # (d, R+rr)
    "w_dq": ("D", None),
    "w_uq": ("D", "M", None),            # (qr, H, nope+rr)
    "w_q": ("D", "M", None),
    "w_uk": ("D", "M", None),            # (R, H, nope)
    "w_uv": ("D", "M", None),            # (R, H, vd)
    "w_o": ("M", None, "D"),             # (H, vd, d)
    # dense FFN
    "w_gate": ("D", "M"),                # (d, ff)
    "w_up": ("D", "M"),
    "w_down": ("M", "D"),                # (ff, d)
    # MoE experts (E, d, ff)/(E, ff, d): expert-parallel on E, FSDP on d
    "we_gate": ("M", "D", None),
    "we_up": ("M", "D", None),
    "we_down": ("M", None, "D"),
    "router": ("D", None),               # (d, E)
    # mamba
    "w_in": ("D", "M"),                  # (d, 2di)
    "conv": (None, "M"),                 # (kw, di)
    "w_x_dbc": ("M", None),              # (di, r+2s)
    "w_dt": (None, "M"),                 # (r, di)
    "dt_bias": ("M",),
    "A_log": ("M", None),                # (di, S)
    "D": ("M",),
    "w_out": ("M", "D"),                 # (di, d)
    # rwkv
    "w_r": ("D", "M"), "w_k": ("D", "M"), "w_v": ("D", "M"),
    "w_g": ("D", "M"),
    "decay_A": ("D", None), "decay_B": (None, "M"),
    "u": ("M", None),                    # (h, hd)
    "cm_k": ("D", "M"), "cm_v": ("M", "D"), "cm_r": ("D", "M"),
}

def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.SequenceKey):
            continue
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def param_pspec(path, leaf, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (divisibility-checked)."""
    name = _leaf_name(path)
    shape = leaf.shape
    rule = _RULES.get(name)
    if rule is None:
        return P()  # norms, biases, mu, ... replicated
    base = len(rule)
    lead = len(shape) - base
    if lead < 0:
        return P()
    dp = _dp(mesh)
    axis_of = {"M": "model", "D": dp}

    def flat(ax) -> set:
        return set(ax) if isinstance(ax, tuple) else {ax}

    spec: list = [None] * len(shape)
    used: set = set()
    for i, want in enumerate(rule):
        if want is None:
            continue
        dim = lead + i
        for ax in (axis_of[want], axis_of["D" if want == "M" else "M"]):
            if ax is None or flat(ax) & used:
                continue
            if shape[dim] % _axis_size(mesh, ax) == 0 and \
                    shape[dim] >= _axis_size(mesh, ax) and shape[dim] > 1:
                spec[dim] = ax
                used |= flat(ax)
                break
    return P(*spec)


def param_sharding_tree(abstract_params, mesh: Mesh):
    """NamedSharding pytree matching ``abstract_params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh)),
        abstract_params)


def batch_pspec(mesh: Mesh, *, seq_shard: bool = False) -> P:
    """(B, S) token batches: batch over the DP group; optionally sequence
    over 'model' (sequence parallelism for very long prefill)."""
    dp = _dp(mesh)
    return P(dp, "model" if seq_shard else None)


def activation_pspec(mesh: Mesh) -> P:
    dp = _dp(mesh)
    return P(dp, None, None)


def cache_pspec(path, leaf, mesh: Mesh, *, batch: int,
                shard_seq_when_small_batch: bool = True) -> P:
    """Decode caches.  Normal case: batch over DP, heads over model.
    long-context batch=1: heads rarely divide — shard the *sequence* dim
    over 'model' instead (each shard holds a KV stripe; the online-softmax
    combine is a small cross-shard reduction)."""
    name = _leaf_name(path)
    shape = leaf.shape
    dp = _dp(mesh)
    dp_size = _axis_size(mesh, dp)
    spec: list = [None] * len(shape)
    if name in ("k", "v"):            # (periods?, B, Hkv, N, hd)
        b_dim = len(shape) - 4
        h_dim, n_dim = b_dim + 1, b_dim + 2
        if batch % dp_size == 0 and batch > 1:
            spec[b_dim] = dp
        if shape[h_dim] % mesh.shape["model"] == 0:
            spec[h_dim] = "model"
        elif shard_seq_when_small_batch and \
                shape[n_dim] % mesh.shape["model"] == 0:
            spec[n_dim] = "model"
    elif name == "c":                  # MLA latent (periods?, B, N, R+rr)
        b_dim = len(shape) - 3
        if batch % dp_size == 0 and batch > 1:
            spec[b_dim] = dp
        if shape[b_dim + 1] % mesh.shape["model"] == 0:
            spec[b_dim + 1] = "model"
    elif name in ("h", "S", "conv", "shift"):  # ssm/rwkv states
        b_dim = len(shape) - (3 if name in ("h", "conv") else
                              4 if name == "S" else 2)
        if batch % dp_size == 0 and batch > 1:
            spec[b_dim] = dp
        # d_inner / heads over model where divisible
        for dim in range(b_dim + 1, len(shape)):
            if spec[dim] is None and shape[dim] % mesh.shape["model"] == 0 \
                    and shape[dim] >= mesh.shape["model"]:
                spec[dim] = "model"
                break
    return P(*spec)


def named_sharding_tree(abstract_tree, mesh: Mesh, pspec_fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, pspec_fn(path, leaf, mesh)),
        abstract_tree)
