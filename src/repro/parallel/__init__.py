from .sharding import (  # noqa: F401
    DP_AXES,
    batch_pspec,
    cache_pspec,
    named_sharding_tree,
    param_pspec,
    param_sharding_tree,
)
