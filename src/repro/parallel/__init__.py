from .sharding import (  # noqa: F401
    DP_AXES,
    ServeTP,
    batch_pspec,
    cache_pspec,
    choose_serve_plan,
    named_sharding_tree,
    param_pspec,
    param_sharding_tree,
    permute_q_heads,
    q_head_permutation,
    serve_cache_pspec,
    serve_param_pspec,
)
