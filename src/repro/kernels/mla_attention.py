"""TL-generated MLA (multi-head latent attention) kernel — paper Table 2.

DeepSeek-V2/V3 MLA with the *absorbed* formulation: queries are projected
into the latent KV space (q_nope @ W_UK appended with the decoupled RoPE
tail), so the kernel contracts a (BM, R+Rr) query tile against the shared
(BN, R+Rr) latent cache tile, and the value GEMM reuses the first R latent
columns (TL ``Compute Slice``) — the cache is read **once** for both GEMMs,
which is the whole memory-traffic argument for MLA.

The pallas_call is emitted by the TL translator; see
:func:`repro.kernels.ops.mla_attention` for the batched wrapper.
"""

from __future__ import annotations

from ..core.pipeline import GeneratedKernel, generate_attention_kernel
from ..core.spec import AttnSpec


def make_mla_kernel(num_heads: int, q_len: int, kv_len: int,
                    kv_lora_rank: int = 512, rope_head_dim: int = 64,
                    causal: bool = True, **kw) -> GeneratedKernel:
    spec = AttnSpec.mla(num_heads, kv_lora_rank, rope_head_dim, causal=causal)
    return generate_attention_kernel(spec, q_len, kv_len, **kw)
