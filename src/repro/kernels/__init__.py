from . import ops, ref  # noqa: F401
from .flash_attention import make_flash_kernel, show_tl  # noqa: F401
from .flash_decode import make_decode_kernel  # noqa: F401
from .linear_scan import rwkv6_chunked  # noqa: F401
from .mla_attention import make_mla_kernel  # noqa: F401
from .ops import (  # noqa: F401
    flash_attention,
    flash_decode,
    mla_attention,
    mla_decode,
    paged_flash_decode,
    paged_mla_decode,
)
