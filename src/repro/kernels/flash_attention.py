"""TL-generated fused flash-attention kernel (MHA/GQA/MQA, causal, window).

The ``pl.pallas_call`` + ``BlockSpec`` for this kernel are *emitted by the
TL translation backend* (``repro.core.translate.pallas_backend``) from the
TL program that the sketch/reason stages produce — that is the paper's
contribution and this repo's point.  This module is the conventional
"kernel file" entry: it exposes the generator, and ``show_tl()`` prints the
full derivation (sketch -> TL code) for a given spec.

Use :func:`repro.kernels.ops.flash_attention` for the padded, batched,
jit-ready form.
"""

from __future__ import annotations

from ..core.pipeline import GeneratedKernel, generate_attention_kernel
from ..core.spec import AttnSpec


def make_flash_kernel(spec: AttnSpec, q_len: int, kv_len: int,
                      **kw) -> GeneratedKernel:
    if spec.variant == "mla":
        raise ValueError("use kernels.mla_attention for MLA specs")
    return generate_attention_kernel(spec, q_len, kv_len, **kw)


def show_tl(spec: AttnSpec, q_len: int = 4096, kv_len: int = 4096) -> str:
    k = make_flash_kernel(spec, q_len, kv_len)
    return (f"=== TL Sketch ({spec.variant}) ===\n{k.sketch_text}\n"
            f"=== TL Code (BM={k.blocks.bm}, BN={k.blocks.bn}) ===\n{k.tl_text}")
