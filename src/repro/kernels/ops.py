"""Public jit-ready wrappers around the TL-generated Pallas kernels.

These own everything the kernel proper does not: dtype normalisation,
sequence padding to block multiples, GQA/MQA head-geometry bookkeeping, the
decode-time q-head->row remapping, and un-padding of results.  All shape
decisions are static so every wrapper jits cleanly.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..core.pipeline import cached_kernel
from ..core.reason import resolve_num_splits
from ..core.spec import AttnSpec

_DT = {jnp.bfloat16.dtype: "bf16", jnp.float32.dtype: "f32",
       jnp.float16.dtype: "f16"}


def _pad_rows(x, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _variant(hq: int, hkv: int) -> str:
    if hkv == 1 and hq > 1:
        return "mqa"
    if hq == hkv:
        return "mha"
    return "gqa"


def _quant_args(pool, scales):
    """Detect an int8-quantized page pool and normalise its per-page
    scales.  Returns ``(kv_dtype, scale_args)``: the spec's layout flag
    plus the f32 scale vectors to pass between the block table and the
    regular kernel operands (see ``translate_pallas``).  ``scales`` is a
    single ``(P,)`` array (MLA latent pool) or a (k_scale, v_scale)
    tuple; a float pool takes no scales."""
    if pool.dtype != jnp.int8:
        if scales is not None:
            raise ValueError("per-page scales supplied for a non-int8 "
                             f"pool of dtype {pool.dtype}")
        return None, ()
    if scales is None:
        raise ValueError("int8 page pools need per-page absmax scales "
                         "(kv_scales= / c_scale=)")
    if not isinstance(scales, (tuple, list)):
        scales = (scales,)
    return "int8", tuple(jnp.asarray(s, jnp.float32).reshape(-1)
                         for s in scales)


def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    interpret: bool = True,
    target: str = "v5e",
    causal_block_skip: bool = True,
):
    """Fused flash attention via the TL pipeline.

    q: (B, Hq, M, D); k/v: (B, Hkv, N, D).  Returns (B, Hq, M, D) in q.dtype.
    """
    b, hq, m, d = q.shape
    hkv, n = k.shape[1], k.shape[2]
    spec = AttnSpec(variant=_variant(hq, hkv), num_q_heads=hq,
                    num_kv_heads=hkv, head_dim=d, causal=causal,
                    window=window, dtype=_DT[q.dtype])
    kern = cached_kernel(spec, m, n, target, interpret, causal_block_skip)
    bm, bn = kern.blocks.bm, kern.blocks.bn
    qp = _pad_rows(q, 2, bm)
    kp = _pad_rows(k, 2, bn)
    vp = _pad_rows(v, 2, bn)
    out = kern.pallas_fn(qp, kp, vp)
    return out[:, :, :m, :]


def mla_attention(
    q_latent, c_kv, *,
    causal: bool = True,
    interpret: bool = True,
    target: str = "v5e",
    kv_lora_rank: int = 512,
    rope_head_dim: int = 64,
):
    """Absorbed multi-head latent attention (DeepSeek V2/V3).

    q_latent: (B, H, M, R+Rr) — queries already absorbed into latent space
    (q_nope @ W_UK plus the decoupled RoPE tail).  c_kv: (B, N, R+Rr) latent
    KV cache with the shared k_rope tail appended.  Returns (B, H, M, R)
    latent outputs (caller up-projects with the absorbed W_UV @ W_O).
    """
    b, h, m, dq = q_latent.shape
    n = c_kv.shape[1]
    assert dq == kv_lora_rank + rope_head_dim
    spec = AttnSpec.mla(h, kv_lora_rank, rope_head_dim, causal=causal,
                        dtype=_DT[q_latent.dtype])
    kern = cached_kernel(spec, m, n, target, interpret, True)
    bm, bn = kern.blocks.bm, kern.blocks.bn
    qp = _pad_rows(q_latent, 2, bm)
    cp = _pad_rows(c_kv, 1, bn)
    out = kern.pallas_fn(qp, cp)
    return out[:, :, :m, :]


def _norm_cache_len(cache_len, batch: int, capacity: int):
    """Normalise ``cache_len`` to a (B,) int32 vector for the runtime-length
    decode kernels.  Accepts None (full capacity), a python int, a traced
    scalar, or a per-request (B,) vector — the serving engine's
    length-heterogeneous decode batches."""
    if cache_len is None:
        return jnp.full((batch,), capacity, jnp.int32)
    lens = jnp.asarray(cache_len, jnp.int32).reshape(-1)
    return jnp.broadcast_to(lens, (batch,))


def flash_decode(
    q, k_cache, v_cache, *,
    cache_len=None,
    num_splits: Optional[int] = None,
    shards: int = 1,
    interpret: bool = True,
    target: str = "v5e",
):
    """Single-token decode against a KV cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, N, D).  ``cache_len`` is the number
    of valid cache entries — a *runtime* quantity: a python int, a traced
    scalar, or a per-request (B,) vector for length-heterogeneous batches.
    The kernel is compiled once per cache *capacity* N (the caller's length
    bucket) and masks/skips past ``cache_len`` at run time, so serving a
    growing cache inside one bucket never retraces.

    TPU adaptation: GPU FlashDecoding parallelises KV splits across SMs.
    On TPU the MXU wants >=8 rows, so the G = Hq/Hkv query heads of one KV
    head are laid out as *rows* of a single q tile (one MXU pass per KV
    head).  KV-split parallelism is the reasoned ``num_splits`` decision:
    ``None`` lets the reasoning stage split the KV axis when
    ``B * Hkv`` under-fills the device for this bucket (Flash-Decoding);
    an explicit int forces that many splits (clamped to whole KV tiles).
    One kernel is compiled per (bucket, splits).  ``shards`` (model-axis
    mesh width of a sharded serving engine) rescales the reasoned choice
    to per-shard rows — pass the *global* row count, not the local one.
    """
    b, hq, one, d = q.shape
    assert one == 1, "decode takes exactly one new token"
    hkv, n = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    # q heads -> rows: (B, Hq, 1, D) -> (B, Hkv, G, D)
    q_rows = q.reshape(b, hkv, g, d)
    spec = AttnSpec(variant="mha", num_q_heads=hkv, num_kv_heads=hkv,
                    head_dim=d, causal=False, mode="decode",
                    dtype=_DT[q.dtype])
    splits = resolve_num_splits(num_splits, rows=b * hkv, kv_len=n,
                                page_size=None, target=target,
                                shards=shards)
    kern = cached_kernel(spec, g, n, target, interpret, False, splits)
    bm, bn = kern.blocks.bm, kern.blocks.bn
    qp = _pad_rows(q_rows, 2, bm)
    kp = _pad_rows(k_cache, 2, bn)
    vp = _pad_rows(v_cache, 2, bn)
    lens = _norm_cache_len(cache_len, b, n)
    out = kern.pallas_fn(lens, qp, kp, vp)         # (B, Hkv, Gpad, D)
    return out[:, :, :g, :].reshape(b, hq, 1, d)


def paged_flash_decode(
    q, k_pool, v_pool, block_tables, *,
    cache_len=None,
    kv_scales=None,
    num_splits: Optional[int] = None,
    shards: int = 1,
    interpret: bool = True,
    target: str = "v5e",
):
    """Single-token decode against a *paged* KV cache.

    q: (B, Hq, 1, D).  ``k_pool``/``v_pool``: (P, Hkv, page_size, D) page
    pools shared by every request; ``block_tables``: (B, Tp) int32 mapping
    each row's logical page j to a physical pool page (entries past the
    row's ``ceil(cache_len / page_size)`` used pages must still be valid
    pool indices — pad with a reserved page).  ``cache_len`` follows
    :func:`flash_decode` (int / traced scalar / per-request (B,) vector).

    ``kv_scales``: required iff the pools are int8 — a ``(k_scale,
    v_scale)`` pair of per-page ``(P,)`` f32 absmax scales; the kernel
    dequantizes each gathered page tile before QK^T.

    The kernel is compiled once per *bucket capacity* ``Tp * page_size``
    and per page size — never per pool size P, cache length, or table
    contents: pools and tables are runtime data, so a growing paged cache
    inside one bucket never retraces.  ``num_splits`` follows
    :func:`flash_decode`; paged splits stay page-aligned, so each split's
    gather reads whole pages.
    """
    b, hq, one, d = q.shape
    assert one == 1, "decode takes exactly one new token"
    hkv, ps = k_pool.shape[1], k_pool.shape[2]
    tbl = jnp.asarray(block_tables, jnp.int32)
    bucket = tbl.shape[-1] * ps
    g = hq // hkv
    q_rows = q.reshape(b, hkv, g, d)
    kv_dt, scales = _quant_args(k_pool, kv_scales)
    spec = AttnSpec(variant="mha", num_q_heads=hkv, num_kv_heads=hkv,
                    head_dim=d, causal=False, mode="decode",
                    dtype=_DT[q.dtype], page_size=ps, kv_dtype=kv_dt)
    splits = resolve_num_splits(num_splits, rows=b * hkv,
                                kv_len=bucket, page_size=ps,
                                target=target, shards=shards)
    kern = cached_kernel(spec, g, bucket, target, interpret, False, splits)
    qp = _pad_rows(q_rows, 2, kern.blocks.bm)
    lens = _norm_cache_len(cache_len, b, bucket)
    out = kern.pallas_fn(lens, tbl, *scales, qp, k_pool, v_pool)
    return out[:, :, :g, :].reshape(b, hq, 1, d)          # (B, Hkv, Gpad, D)


def paged_flash_prefill(
    q, k_pool, v_pool, block_tables, *,
    hist_len,
    chunk_cap: Optional[int] = None,
    kv_scales=None,
    interpret: bool = True,
    target: str = "v5e",
):
    """One prompt *chunk* of causal attention against a paged KV cache.

    q: (B, Hq, C, D) — C chunk tokens sitting at runtime cache positions
    ``hist_len .. hist_len + C - 1``; ``k_pool``/``v_pool``/``block_tables``
    follow :func:`paged_flash_decode`.  The chunk's own K/V must already be
    written into the pages (the model layer scatters before attending), so
    row i attends causally to cache positions ``0 .. hist_len + i``.

    ``hist_len`` is the number of cache entries *preceding* the chunk — a
    python int, a traced scalar, or a per-request (B,) vector — and is
    runtime data: the kernel is compiled once per (chunk capacity C, bucket
    capacity ``Tp * page_size``, page size), never per chunk position, so a
    long prompt prefilled chunk-by-chunk retraces nothing after the first
    chunk.  Rows past the chunk's true length (a padded tail chunk) return
    garbage the caller discards.

    ``chunk_cap``: optional static capacity ≥ C to pad the chunk axis to
    before kernel generation — a scheduler dispatching variable-size
    budgeted chunks passes its (bounded) cap set here so the kernel cache
    is keyed on caps, never on the actual chunk sizes the budget produced.
    """
    b, hq, c, d = q.shape
    hkv, ps = k_pool.shape[1], k_pool.shape[2]
    if chunk_cap is not None:
        if chunk_cap < c:
            raise ValueError(f"chunk_cap {chunk_cap} < chunk length {c}")
        q = _pad_rows(q, 2, chunk_cap)
    cap = q.shape[2]
    tbl = jnp.asarray(block_tables, jnp.int32)
    bucket = tbl.shape[-1] * ps
    kv_dt, scales = _quant_args(k_pool, kv_scales)
    spec = AttnSpec(variant=_variant(hq, hkv), num_q_heads=hq,
                    num_kv_heads=hkv, head_dim=d, causal=True,
                    mode="chunk_prefill", dtype=_DT[q.dtype], page_size=ps,
                    kv_dtype=kv_dt)
    kern = cached_kernel(spec, cap, bucket, target, interpret, True)
    qp = _pad_rows(q, 2, kern.blocks.bm)
    lens = _norm_cache_len(hist_len, b, 0)
    out = kern.pallas_fn(lens, tbl, *scales, qp, k_pool, v_pool)
    return out[:, :, :c, :]


def paged_mla_prefill(
    q_latent, c_pool, block_tables, *,
    hist_len,
    chunk_cap: Optional[int] = None,
    c_scale=None,
    interpret: bool = True,
    target: str = "v5e",
    kv_lora_rank: int = 512,
    rope_head_dim: int = 64,
    shard_axis: Optional[str] = None,
):
    """One prompt chunk of causal MLA attention against a paged latent
    cache.  q_latent: (B, H, C, R+Rr); ``c_pool``/``block_tables``/
    ``hist_len``/``chunk_cap`` follow :func:`paged_flash_prefill`;
    ``c_scale`` is the (P,) f32 per-page scale vector, required iff the
    latent pool is int8.  ``shard_axis``: sequence-sharded serving — the
    caller passes this rank's table slice and *local* ``hist_len`` (global
    minus the rank's page offset; may go negative past the valid region)
    and the kernel LSE-merges partial states across the mesh axis."""
    b, h, c, dq = q_latent.shape
    ps = c_pool.shape[1]
    if chunk_cap is not None:
        if chunk_cap < c:
            raise ValueError(f"chunk_cap {chunk_cap} < chunk length {c}")
        q_latent = _pad_rows(q_latent, 2, chunk_cap)
    cap = q_latent.shape[2]
    tbl = jnp.asarray(block_tables, jnp.int32)
    bucket = tbl.shape[-1] * ps
    kv_dt, scales = _quant_args(c_pool, c_scale)
    spec = AttnSpec.mla(h, kv_lora_rank, rope_head_dim, causal=True,
                        mode="chunk_prefill", dtype=_DT[q_latent.dtype],
                        page_size=ps, kv_dtype=kv_dt)
    kern = cached_kernel(spec, cap, bucket, target, interpret, True, 1,
                         shard_axis)
    qp = _pad_rows(q_latent, 2, kern.blocks.bm)
    lens = _norm_cache_len(hist_len, b, 0)
    out = kern.pallas_fn(lens, tbl, *scales, qp, c_pool)
    return out[:, :, :c, :]


def paged_flash_verify(
    q, k_pool, v_pool, block_tables, *,
    hist_len,
    chunk_cap: Optional[int] = None,
    kv_scales=None,
    num_splits: Optional[int] = None,
    shards: int = 1,
    interpret: bool = True,
    target: str = "v5e",
):
    """Speculative-decode verification: K+1 candidate tokens of causal
    attention against a paged KV cache, returning per-position outputs.

    q: (B, Hq, C, D) — the committed token plus the drafts, sitting at
    runtime cache positions ``hist_len .. hist_len + C - 1`` with their K/V
    already scattered into the pages (like :func:`paged_flash_prefill`, and
    the caller rolls those pages back past the accepted length).  Row i's
    output is the attention for position ``hist_len + i``, so the caller's
    logits at row i decide draft i+1 — one dispatch verifies the whole
    draft window.

    The TL mode is ``verify``: chunk_prefill's runtime history-offset
    tiling *plus* decode's split-KV partitioning — ``num_splits`` follows
    :func:`paged_flash_decode` (``None`` lets the reasoning stage consult
    the autotuner's scored split search for this grid; verify grids expose
    ``B * Hq`` programs).  Compiled once per (chunk capacity, bucket
    capacity, page size, splits).
    """
    b, hq, c, d = q.shape
    hkv, ps = k_pool.shape[1], k_pool.shape[2]
    if chunk_cap is not None:
        if chunk_cap < c:
            raise ValueError(f"chunk_cap {chunk_cap} < draft window {c}")
        q = _pad_rows(q, 2, chunk_cap)
    cap = q.shape[2]
    tbl = jnp.asarray(block_tables, jnp.int32)
    bucket = tbl.shape[-1] * ps
    kv_dt, scales = _quant_args(k_pool, kv_scales)
    spec = AttnSpec(variant=_variant(hq, hkv), num_q_heads=hq,
                    num_kv_heads=hkv, head_dim=d, causal=True,
                    mode="verify", dtype=_DT[q.dtype], page_size=ps,
                    kv_dtype=kv_dt)
    splits = resolve_num_splits(num_splits, rows=b * hq, kv_len=bucket,
                                mode="verify", page_size=ps, target=target,
                                shards=shards)
    kern = cached_kernel(spec, cap, bucket, target, interpret, True, splits)
    qp = _pad_rows(q, 2, kern.blocks.bm)
    lens = _norm_cache_len(hist_len, b, 0)
    out = kern.pallas_fn(lens, tbl, *scales, qp, k_pool, v_pool)
    return out[:, :, :c, :]


def paged_mla_verify(
    q_latent, c_pool, block_tables, *,
    hist_len,
    chunk_cap: Optional[int] = None,
    c_scale=None,
    num_splits: Optional[int] = None,
    shards: int = 1,
    interpret: bool = True,
    target: str = "v5e",
    kv_lora_rank: int = 512,
    rope_head_dim: int = 64,
    shard_axis: Optional[str] = None,
):
    """Speculative-decode verification against a paged latent cache.
    q_latent: (B, H, C, R+Rr); everything else follows
    :func:`paged_flash_verify` (MLA verify grids expose ``B * H``
    programs); ``shard_axis`` follows :func:`paged_mla_prefill`."""
    b, h, c, dq = q_latent.shape
    ps = c_pool.shape[1]
    if chunk_cap is not None:
        if chunk_cap < c:
            raise ValueError(f"chunk_cap {chunk_cap} < draft window {c}")
        q_latent = _pad_rows(q_latent, 2, chunk_cap)
    cap = q_latent.shape[2]
    tbl = jnp.asarray(block_tables, jnp.int32)
    bucket = tbl.shape[-1] * ps
    kv_dt, scales = _quant_args(c_pool, c_scale)
    spec = AttnSpec.mla(h, kv_lora_rank, rope_head_dim, causal=True,
                        mode="verify", dtype=_DT[q_latent.dtype],
                        page_size=ps, kv_dtype=kv_dt)
    splits = resolve_num_splits(num_splits, rows=b * h, kv_len=bucket,
                                mode="verify", page_size=ps, target=target,
                                shards=shards)
    kern = cached_kernel(spec, cap, bucket, target, interpret, True, splits,
                         shard_axis)
    qp = _pad_rows(q_latent, 2, kern.blocks.bm)
    lens = _norm_cache_len(hist_len, b, 0)
    out = kern.pallas_fn(lens, tbl, *scales, qp, c_pool)
    return out[:, :, :c, :]


def paged_mla_decode(
    q_latent, c_pool, block_tables, *,
    cache_len=None,
    c_scale=None,
    num_splits: Optional[int] = None,
    shards: int = 1,
    interpret: bool = True,
    target: str = "v5e",
    kv_lora_rank: int = 512,
    rope_head_dim: int = 64,
    shard_axis: Optional[str] = None,
):
    """Single-token MLA decode against a paged latent cache.

    ``c_pool``: (P, page_size, R+Rr) latent page pool; ``block_tables`` and
    ``cache_len`` follow :func:`paged_flash_decode`, ``num_splits``
    follows :func:`flash_decode` (MLA exposes only B launch programs — one
    latent head — so splitting kicks in earliest here).  Compiled per
    (bucket capacity ``Tp * page_size``, page size, splits) only.
    """
    b, h, one, dq = q_latent.shape
    assert one == 1
    ps = c_pool.shape[1]
    tbl = jnp.asarray(block_tables, jnp.int32)
    bucket = tbl.shape[-1] * ps
    kv_dt, scales = _quant_args(c_pool, c_scale)
    spec = AttnSpec.mla(h, kv_lora_rank, rope_head_dim, causal=False,
                        mode="decode", dtype=_DT[q_latent.dtype],
                        page_size=ps, kv_dtype=kv_dt)
    splits = resolve_num_splits(num_splits, rows=b, kv_len=bucket,
                                page_size=ps, target=target, shards=shards)
    kern = cached_kernel(spec, h, bucket, target, interpret, False, splits,
                         shard_axis)
    # heads -> rows: (B, H, 1, Dq) -> (B, 1, H, Dq)
    q_rows = q_latent.reshape(b, 1, h, dq)
    qp = _pad_rows(q_rows, 2, kern.blocks.bm)
    lens = _norm_cache_len(cache_len, b, bucket)
    out = kern.pallas_fn(lens, tbl, *scales, qp, c_pool)  # (B, 1, Hpad, R)
    return out[:, 0, :h, :].reshape(b, h, 1, kv_lora_rank)


def mla_decode(
    q_latent, c_cache, *,
    cache_len=None,
    num_splits: Optional[int] = None,
    shards: int = 1,
    interpret: bool = True,
    target: str = "v5e",
    kv_lora_rank: int = 512,
    rope_head_dim: int = 64,
):
    """Single-token MLA decode: all H latent queries share the single latent
    cache, so the H heads are the tile rows (same TPU adaptation as
    :func:`flash_decode`).  Like :func:`flash_decode`, compiled per cache
    *capacity* (and per ``num_splits``); ``cache_len`` (int, traced
    scalar, or per-request (B,) vector) is runtime data."""
    b, h, one, dq = q_latent.shape
    assert one == 1
    n = c_cache.shape[1]
    spec = AttnSpec.mla(h, kv_lora_rank, rope_head_dim, causal=False,
                        mode="decode", dtype=_DT[q_latent.dtype])
    splits = resolve_num_splits(num_splits, rows=b, kv_len=n,
                                page_size=None, target=target,
                                shards=shards)
    kern = cached_kernel(spec, h, n, target, interpret, False, splits)
    bm, bn = kern.blocks.bm, kern.blocks.bn
    # heads -> rows: (B, H, 1, Dq) -> (B, 1, H, Dq)
    q_rows = q_latent.reshape(b, 1, h, dq)
    qp = _pad_rows(q_rows, 2, bm)
    cp = _pad_rows(c_cache, 1, bn)
    lens = _norm_cache_len(cache_len, b, n)
    out = kern.pallas_fn(lens, qp, cp)             # (B, 1, Hpad, R)
    return out[:, 0, :h, :].reshape(b, h, 1, kv_lora_rank)
