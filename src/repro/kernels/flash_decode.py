"""TL-generated decode attention (FlashDecoding re-grounded for TPU).

GPU FlashDecoding splits the KV cache across SMs and merges partial
softmaxes.  The TPU adaptation (DESIGN.md §2): the MXU wants >=8-row tiles,
so the G = Hq/Hkv query heads that share a KV head become the *rows* of one
q tile — a single MXU pass per KV head per KV block — and the KV dimension
rides the sequential grid with the online-softmax state in VMEM scratch.
The same TL program as prefill serves decode with different parameters
(M = G, causal off, bounds mask at the cache length), which is the paper's
"same sketch, different reasoning" parameterisation story.

Batched wrappers: :func:`repro.kernels.ops.flash_decode` / ``mla_decode``.
"""

from __future__ import annotations

from ..core.pipeline import GeneratedKernel, generate_attention_kernel
from ..core.spec import AttnSpec


def make_decode_kernel(num_kv_heads: int, q_rows: int, cache_len: int,
                       head_dim: int, **kw) -> GeneratedKernel:
    spec = AttnSpec(variant="mha", num_q_heads=num_kv_heads,
                    num_kv_heads=num_kv_heads, head_dim=head_dim,
                    causal=False, mode="decode")
    return generate_attention_kernel(spec, q_rows, cache_len, **kw)
