"""TL-generated decode attention (FlashDecoding re-grounded for TPU).

GPU FlashDecoding splits the KV cache across SMs and merges partial
softmaxes.  The TPU adaptation (DESIGN.md §2): the MXU wants >=8-row tiles,
so the G = Hq/Hkv query heads that share a KV head become the *rows* of one
q tile — a single MXU pass per KV head per KV block.  The KV dimension
rides the sequential grid with the online-softmax state in VMEM scratch —
until the reasoning stage decides the launch under-fills the device
(``reason.choose_num_splits``), at which point it emits
``KV_SPLIT``/``NUM_SPLITS`` and the KV axis is partitioned across a
*parallel* grid dimension whose programs write partial ``(acc, m, l)``
state, LSE-merged by a small combine kernel — FlashDecoding's SM split,
expressed as TL reasoning.  The same TL program as prefill serves decode
with different parameters (M = G, causal off), which is the paper's "same
sketch, different reasoning" parameterisation story.

Decode programs are *runtime-length*: the reasoning stage binds ``N`` to a
bucket capacity and the true cache length is a scalar kernel operand
(``fn(kv_len, q, k, v)``), so one compiled kernel serves every decode step
whose cache fits the bucket — the serving engine compiles O(log max_len)
kernels total instead of one per step.

Specs with ``page_size`` set additionally take a per-row *block table*
operand (``fn(kv_len, block_tables, q, k_pool, v_pool)``): the KV cache is
then a pool of fixed-size pages gathered through the table by the kernel's
BlockSpec index maps — the PagedAttention serving layout, expressed as TL
reasoning (``PAGE_SIZE`` aligned with ``BN``) rather than a hand-patched
kernel.

Batched wrappers: :func:`repro.kernels.ops.flash_decode` / ``mla_decode`` /
``paged_flash_decode`` / ``paged_mla_decode``.
"""

from __future__ import annotations

from ..core.pipeline import GeneratedKernel, generate_attention_kernel
from ..core.spec import AttnSpec


def make_decode_kernel(num_kv_heads: int, q_rows: int, bucket_len: int,
                       head_dim: int, **kw) -> GeneratedKernel:
    """Decode kernel for a KV *bucket capacity* of ``bucket_len`` entries.

    The returned kernel's ``pallas_fn``/``oracle_fn`` take a leading
    runtime ``kv_len`` operand (see module docstring).  Pass
    ``num_splits=`` to force a split-KV launch (clamped; both backends
    lower the identical split/merge)."""
    spec = AttnSpec(variant="mha", num_q_heads=num_kv_heads,
                    num_kv_heads=num_kv_heads, head_dim=head_dim,
                    causal=False, mode="decode")
    return generate_attention_kernel(spec, q_rows, bucket_len, **kw)
