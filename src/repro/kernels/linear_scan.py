"""Chunked linear-recurrence Pallas kernel (RWKV-6 "Finch").

The paper's technique targets attention; RWKV-6 is attention-free
(DESIGN.md §Arch-applicability), so this kernel is hand-written — but it is
*blocked the TL way*: an outer sequential grid dimension carries the
recurrent state in VMEM scratch (TL: ``Allocate S in register``), chunk
tiles stream HBM->VMEM via BlockSpecs (TL: ``Copy .. from global to
shared``), and the intra-chunk work is two MXU GEMMs chained through a
layout re-declaration (TL: ``Reshape``) — exactly the statement vocabulary
of the attention kernels.

Math (per head; state S in R^{Dk x Dv}; d_t = exp(-exp(w_t)) data-dependent
decay; u the current-token bonus):

    o_t = r_t (S_{t-1} + u k_t v_t^T),   S_t = diag(d_t) S_{t-1} + k_t v_t^T

Chunked over L tokens with inclusive log-decay c_t = sum_{s<=t} -exp(w_s):

    intra: A[t,s] = (r_t * e^{c_{t-1}}) . (k_s * e^{-c_s}),  s < t
           A[t,t] = r_t . (u * k_t)
    o      = A @ V + (r * e^{c_{t-1}}) @ S_0
    S_L    = diag(e^{c_L}) S_0 + (k * e^{c_L - c_s})^T @ V
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros(s_ref.shape, s_ref.dtype)

    r = r_ref[...].reshape(r_ref.shape[-2:]).astype(jnp.float32)
    k = k_ref[...].reshape(k_ref.shape[-2:]).astype(jnp.float32)
    v = v_ref[...].reshape(v_ref.shape[-2:]).astype(jnp.float32)
    w = w_ref[...].reshape(w_ref.shape[-2:]).astype(jnp.float32)
    u = u_ref[...].reshape(u_ref.shape[-1:]).astype(jnp.float32)

    neg_ew = -jnp.exp(w)                       # log per-step decay  (L, Dk)
    c_inc = jnp.cumsum(neg_ew, axis=0)         # inclusive log decay (L, Dk)
    c_prev = c_inc - neg_ew                    # exclusive (c_{t-1})
    c_last = c_inc[-1:, :]                     # (1, Dk)

    r_dec = r * jnp.exp(c_prev)                # r_t * e^{c_{t-1}}
    k_grow = k * jnp.exp(-c_inc)               # k_s * e^{-c_s}
    k_tail = k * jnp.exp(c_last - c_inc)       # k_s * e^{c_L - c_s}

    # intra-chunk "attention" (strictly lower triangular) + u-bonus diagonal
    a = jnp.dot(r_dec, k_grow.T, preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(cols < rows, a, 0.0)
    diag = jnp.sum(r * (u[None, :] * k), axis=-1)          # (L,)
    o = jnp.dot(a, v, preferred_element_type=jnp.float32)
    o += diag[:, None] * v
    o += jnp.dot(r_dec, s_ref[...], preferred_element_type=jnp.float32)

    s_ref[...] = jnp.exp(c_last).T * s_ref[...] + jnp.dot(
        k_tail.T, v, preferred_element_type=jnp.float32)

    o_ref[...] = o.reshape(o_ref.shape).astype(o_ref.dtype)


def rwkv6_chunked(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK,
                  interpret: bool = True):
    """r/k/w: (B, H, T, Dk), v: (B, H, T, Dv), u: (H, Dk) -> (B, H, T, Dv).

    T must be a multiple of ``chunk`` (the layer wrapper pads).
    """
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    if t % chunk:
        raise ValueError(f"T={t} not a multiple of chunk={chunk}")
    grid = (b * h, t // chunk)

    tile = lambda d: pl.BlockSpec(
        (1, 1, chunk, d), lambda bh, ci: (bh // h, bh % h, ci, 0))
    u_spec = pl.BlockSpec((1, dk), lambda bh, ci: (bh % h, 0))

    fn = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[tile(dk), tile(dk), tile(dv), tile(dk), u_spec],
        out_specs=tile(dv),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )
    return fn(r, k, v, w, u)
