"""Pure-jnp oracles for every kernel in this package.

These are the closed-form definitions the Pallas kernels (and the TL-jnp
backend) are tested against — slow, obvious, numerically f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(scores, q_len, kv_len, causal, window, kv_valid=None):
    mk = jnp.arange(kv_len)[None, :]
    if kv_valid is None:
        # bottom-right alignment against the buffer (the last q row sees
        # the last key)
        mq = jnp.arange(q_len)[:, None] + (kv_len - q_len)
        keep = jnp.ones((q_len, kv_len), bool)
    else:
        # bottom-right alignment against the *valid* length — q row i sits
        # at absolute position kv_valid - q_len + i, matching
        # ``xla_flash``'s ``q_off = kv_valid - M`` (the chunked-prefill /
        # cached-prefill convention).  ``kv_valid`` may be a scalar or a
        # per-batch-row (B,) vector (length-heterogeneous serving batches).
        kv_valid = jnp.asarray(kv_valid)
        if kv_valid.ndim == 1:
            kv_valid = kv_valid[:, None, None, None]
        mq = jnp.arange(q_len)[:, None] + (kv_valid - q_len)
        keep = mk < kv_valid
        keep = jnp.broadcast_to(keep, jnp.broadcast_shapes(
            keep.shape, scores.shape))
    if causal:
        keep = keep & (mk <= mq)
    if window is not None:
        keep = keep & (mk > mq - window)
    return jnp.where(keep, scores, NEG_INF)


def attention(q, k, v, *, causal=True, window=None, scale=None,
              kv_valid=None):
    """Reference attention.  q: (B, Hq, M, D), k/v: (B, Hkv, N, D[v]).

    GQA/MQA head mapping: query head h reads kv head ``h // (Hq // Hkv)``.
    Computed entirely in f32.
    """
    b, hq, m, d = q.shape
    hkv, n = k.shape[1], k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    g = hq // hkv
    kx = jnp.repeat(k, g, axis=1)
    vx = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhmd,bhnd->bhmn", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    s = _mask(s, m, n, causal, window, kv_valid)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (a query before any visible key) are defined as 0,
    # matching the flash kernels' l==0 guard
    any_live = jnp.any(s > NEG_INF / 2, axis=-1, keepdims=True)
    p = jnp.where(any_live, p, 0.0)
    return jnp.einsum("bhmn,bhnd->bhmd", p, vx.astype(jnp.float32))


def mla_attention(q_latent, c_kv, *, causal=True, scale=None, kv_valid=None,
                  rope_dim=64):
    """Reference absorbed MLA.  q_latent: (B, H, M, R+Rr), c_kv: (B, N, R+Rr)
    where the value payload is the first R latent dims.
    Returns (B, H, M, R)."""
    b, h, m, dq = q_latent.shape
    n = c_kv.shape[1]
    scale = ((128 + rope_dim) ** -0.5) if scale is None else scale
    s = jnp.einsum("bhmd,bnd->bhmn", q_latent.astype(jnp.float32),
                   c_kv.astype(jnp.float32)) * scale
    s = _mask(s, m, n, causal, None, kv_valid)
    p = jax.nn.softmax(s, axis=-1)
    r = dq - rope_dim  # rope tail is appended after the R latent dims
    return jnp.einsum("bhmn,bnr->bhmr", p, c_kv[..., :r].astype(jnp.float32))


def decode_attention(q, k_cache, v_cache, *, cache_len=None, scale=None):
    """One-token decode: q (B, Hq, 1, D) against a (B, Hkv, N, D) cache."""
    return attention(q, k_cache, v_cache, causal=False, scale=scale,
                     kv_valid=cache_len)


# --- linear-recurrence references (RWKV-6 / Mamba-style SSD) ----------------

def rwkv6_scan(r, k, v, w, u):
    """RWKV-6 ("Finch") recurrence, per head, f32 sequential reference.

    r/k: (B, H, T, Dk), v: (B, H, T, Dv), w: (B, H, T, Dk) decay *logits*
    (decay = exp(-exp(w)) data-dependent), u: (H, Dk) bonus.
    State S: (Dk, Dv);  o_t = r_t @ (S + u * k_t v_t^T);  S = diag(d_t) S +
    k_t v_t^T.
    """
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    decay = jnp.exp(-jnp.exp(w.astype(jnp.float32)))

    def head_scan(r1, k1, v1, d1, u1):
        def step(S, xs):
            rt, kt, vt, dt = xs
            kv = kt[:, None] * vt[None, :]
            ot = (rt[None, :] @ (S + u1[:, None] * kv))[0]
            S = dt[:, None] * S + kv
            return S, ot
        S0 = jnp.zeros((dk, dv), jnp.float32)
        _, o = jax.lax.scan(step, S0, (r1, k1, v1, d1))
        return o

    f = jax.vmap(jax.vmap(head_scan, in_axes=(0, 0, 0, 0, 0)),
                 in_axes=(0, 0, 0, 0, None))
    return f(r.astype(jnp.float32), k.astype(jnp.float32),
             v.astype(jnp.float32), decay, u.astype(jnp.float32))


def mamba_scan(x, dt, A, B, C, D):
    """Selective-SSM (Mamba) reference, f32 sequential.

    x: (Bb, T, Din), dt: (Bb, T, Din) (softplus-activated), A: (Din, S),
    B/C: (Bb, T, S), D: (Din,).  Returns (Bb, T, Din).
    """
    bb, t, din = x.shape
    s = A.shape[1]
    dA = jnp.exp(dt[..., None] * A[None, None])          # (Bb,T,Din,S)
    dBx = dt[..., None] * B[:, :, None, :] * x[..., None]

    def seq(dA1, dBx1, C1, x1):
        def step(h, xs):
            da, dbx, c = xs
            h = da * h + dbx
            y = jnp.einsum("ds,s->d", h, c)
            return h, y
        h0 = jnp.zeros((din, s), jnp.float32)
        _, y = jax.lax.scan(step, h0, (dA1, dBx1, C1))
        return y + D[None, :] * x1

    return jax.vmap(seq)(dA.astype(jnp.float32), dBx.astype(jnp.float32),
                         C.astype(jnp.float32), x.astype(jnp.float32))
