"""Serving launcher: batched generation with the ServeEngine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \
      --prompts 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.models import registry, transformer
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attn-impl", default=None)
    args = ap.parse_args()

    cfg = registry.get_reduced(args.arch)
    if args.attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    vision = None
    if cfg.cross_attn_period:
        vision = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.prompts, cfg.num_patches, cfg.vision_d))
    engine = ServeEngine(cfg, params, max_batch=args.prompts,
                         max_len=args.max_len, vision_embeds=vision)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, args.prompt_len))
               for _ in range(args.prompts)]
    t0 = time.time()
    res = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature)
    dt = time.time() - t0
    print(f"[serve] {args.prompts} seqs x {args.new_tokens} new tokens "
          f"in {dt:.2f}s ({args.prompts*args.new_tokens/dt:.1f} tok/s)")
    print("[serve] first sequence:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
