"""Training launcher with a fault-tolerant supervisor loop.

Responsibilities (DESIGN.md §3.1):
  * build mesh + sharded train state (restoring the latest checkpoint if
    one exists — crash/preemption recovery, including onto a different
    mesh shape via restore-with-reshard);
  * deterministic-by-step data (any host can regenerate any shard);
  * step loop with NaN/stall detection: a non-finite step is *skipped*
    in-graph (train.step), and ``bad_step_budget`` consecutive bad steps
    trigger restore-from-checkpoint;
  * periodic async checkpointing + keep-last-k GC;
  * per-step heartbeat line (host, step, loss, tokens/s) — the signal a
    cluster supervisor uses for straggler detection.

Single-process form; at multi-host scale the same loop runs per host with
jax.distributed initialised and per-host data shards (data/pipeline.py
row_start/rows).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointManager
from repro.data import SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.parallel import sharding as shd
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainState, make_train_step, train_state_init


def run(arch: str, *, reduced: bool = True, steps: int = 20, batch: int = 8,
        seq: int = 128, grad_accum: int = 1, ckpt_dir: str | None = None,
        ckpt_every: int = 10, keep: int = 3, bad_step_budget: int = 3,
        lr: float = 3e-4, model_axis: int = 1, seed: int = 0,
        log_every: int = 1, attn_impl: str | None = None):
    cfg = (registry.get_reduced(arch) if reduced else
           registry.get_config(arch))
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    mesh = make_host_mesh(model_axis)
    opt_cfg = AdamWConfig(lr=lr, total_steps=max(steps, 2),
                          warmup_steps=max(2, steps // 20))
    data = SyntheticTokens(cfg.vocab_size, seq, batch, seed=seed)

    with mesh:
        state = train_state_init(jax.random.PRNGKey(seed), cfg, opt_cfg)
        params_sh = shd.param_sharding_tree(state.params, mesh)
        state_sh = TrainState(
            params=params_sh,
            opt_state={"m": shd.param_sharding_tree(state.opt_state["m"], mesh),
                       "v": shd.param_sharding_tree(state.opt_state["v"], mesh),
                       "count": NamedSharding(mesh, P())},
            step=NamedSharding(mesh, P()))
        state = jax.device_put(state, state_sh)
        dpax = shd._dp(mesh)
        batch_sh = NamedSharding(mesh, P(dpax, None))

        step_fn = jax.jit(make_train_step(cfg, opt_cfg, grad_accum),
                          in_shardings=(state_sh, {"tokens": batch_sh,
                                                   "labels": batch_sh}),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,))

        mgr = None
        start_step = 0
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir, keep=keep)
            got, restored = mgr.restore_latest(state, shardings=state_sh)
            if restored is not None:
                state, start_step = restored, got
                print(f"[train] restored checkpoint step {got}")

        bad = 0
        losses = []
        t0 = time.time()
        for step in range(start_step, steps):
            b = data.batch(step)
            jb = {k: jax.device_put(jnp.asarray(v), batch_sh)
                  for k, v in b.items()}
            state, metrics = step_fn(state, jb)
            loss = float(metrics["loss"])
            finite = bool(metrics["finite"])
            losses.append(loss)
            if not finite:
                bad += 1
                print(f"[train] step {step}: NON-FINITE grads "
                      f"(skipped in-graph, {bad}/{bad_step_budget})")
                if bad >= bad_step_budget and mgr is not None:
                    got, restored = mgr.restore_latest(state, shardings=state_sh)
                    if restored is not None:
                        state = restored
                        print(f"[train] rolled back to step {got}")
                    bad = 0
            else:
                bad = 0
            if step % log_every == 0:
                tps = batch * seq * (step - start_step + 1) / (time.time() - t0)
                print(f"[train] step {step} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"tok/s {tps:,.0f}")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, state)
        if mgr is not None:
            mgr.save(steps, state)
            mgr.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--attn-impl", default=None)
    ap.set_defaults(reduced=True)
    args = ap.parse_args()
    losses = run(args.arch, reduced=args.reduced, steps=args.steps,
                 batch=args.batch, seq=args.seq, grad_accum=args.grad_accum,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 lr=args.lr, model_axis=args.model_axis,
                 attn_impl=args.attn_impl)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
