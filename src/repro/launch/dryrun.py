import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, the abstract (ShapeDtype-
Struct) train state / serve inputs with their NamedShardings, lowers the
appropriate step function, compiles it, and extracts:

  * compiled.memory_analysis()     — proves the cell fits per-device HBM
  * SPMD HLO dot/collective costs  — roofline terms (repro.roofline)

Results are appended to a JSON report (one entry per cell) consumed by
benchmarks/roofline_table.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_size, make_production_mesh
from repro.models import registry, transformer
from repro.parallel import sharding as shd
from repro.roofline import analyze_hlo, roofline_terms
from repro.train.optimizer import AdamWConfig
from repro.train.step import abstract_train_state, make_train_step


def _sds_tree_shardings(mesh, tree, pspec_fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, pspec_fn(path, leaf, mesh)),
        tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               grad_accum: int | None = None,
               attn_chunk: int | None = None,
               seq_shard: bool = True,
               remat_policy: str | None = None,
               donate: bool = True):
    """Returns (lowered, compiled, meta) for one dry-run cell."""
    cfg = registry.get_config(arch)
    if attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=attn_chunk)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    shape = registry.SHAPES[shape_name]
    ok, why = registry.shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"SKIP: {why}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_size(mesh)
    dpax = shd._dp(mesh)
    b = shape.global_batch
    specs = registry.input_specs(cfg, shape)

    # Sequence-parallel residual stream for PREFILL: shards the 32k-512k
    # activations (and the saved carry) over 'model'.  Not used for train
    # baselines: GSPMD's backward resolves the SP<->TP layout conflict by
    # all-gathering full weights per period ("involuntary full remat"
    # warnings), a measured 6x collective regression — see EXPERIMENTS.md
    # §Perf for the hillclimb.  SSM/hybrid keep sequence unsharded (the
    # recurrence is sequential in T).
    act_sh = None
    if seq_shard and shape.kind == "prefill" \
            and not (cfg.rwkv or cfg.hybrid_period) \
            and shape.seq_len % mesh.shape["model"] == 0:
        act_sh = P(dpax, "model", None)
    # expert-parallel buffer sharding: (B, E, C, d) rows over dp, experts
    # over 'model' (the dispatch all-to-all boundary)
    ep_sh = None
    moe_mesh = None
    if cfg.moe and cfg.num_experts % mesh.shape["model"] == 0:
        ep_sh = P(dpax, "model", None, None)
        # shard_map EP interior needs the batch to tile the dp group
        # (batch-1 long-context decode falls back to the GSPMD path)
        if b % dp == 0:
            moe_mesh = (mesh, dpax)
    # attention head sharding: (B, H, T, D) heads over 'model'
    head_sh = None
    lat_sh = None
    hq_eff = max(cfg.num_q_heads, cfg.pad_q_heads_to)
    if not cfg.rwkv and hq_eff % mesh.shape["model"] == 0:
        head_sh = P(dpax, "model", None, None)
    if cfg.mla:
        lat_sh = P(dpax, None, None)

    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig(
                state_dtype="bf16" if cfg.param_count() > 1e11 else "f32")
            ga = grad_accum or max(1, b // dp)
            state = abstract_train_state(cfg, opt_cfg)
            state_sh = jax.tree.map(
                lambda _: None, state)  # placeholder; built below
            params_sh = shd.param_sharding_tree(state.params, mesh)
            opt_sh = {
                "m": shd.param_sharding_tree(state.opt_state["m"], mesh),
                "v": shd.param_sharding_tree(state.opt_state["v"], mesh),
                "count": NamedSharding(mesh, P()),
            }
            from repro.train.step import TrainState
            state_sh = TrainState(params=params_sh, opt_state=opt_sh,
                                  step=NamedSharding(mesh, P()))
            batch_sh = {k: NamedSharding(mesh, P(dpax, *([None] * (len(v.shape) - 1))))
                        for k, v in specs.items()}
            step_fn = make_train_step(cfg, opt_cfg, ga, act_sharding=act_sh,
                                      grad_sharding=params_sh,
                                      ep_sharding=ep_sh,
                                      head_sharding=head_sh,
                                      latent_sharding=lat_sh,
                                      moe_mesh=moe_mesh)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state, specs)
            meta = {"grad_accum": ga, "kind": "train_step"}

        elif shape.kind == "prefill":
            params = transformer.abstract_params(cfg)
            params_sh = shd.param_sharding_tree(params, mesh)
            caches = jax.eval_shape(
                lambda: transformer.init_caches(cfg, b, shape.seq_len))
            caches_sh = _sds_tree_shardings(
                mesh, caches,
                lambda p_, l, m: shd.cache_pspec(p_, l, m, batch=b))
            tok_sh = {k: NamedSharding(
                mesh, P(dpax, *([None] * (len(v.shape) - 1))))
                for k, v in specs.items()}

            def prefill_step(params, caches, inputs):
                logits, _, new_caches = transformer.apply(
                    params, inputs["tokens"], cfg, caches=caches,
                    cache_len=0, act_sharding=act_sh, ep_sharding=ep_sh,
                    head_sharding=head_sh, latent_sharding=lat_sh,
                    moe_mesh=moe_mesh,
                    vision_embeds=inputs.get("vision_embeds"))
                return logits[:, -1], new_caches

            jitted = jax.jit(prefill_step,
                             in_shardings=(params_sh, caches_sh, tok_sh),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params, caches, specs)
            meta = {"kind": "prefill_step"}

        else:  # decode
            params = transformer.abstract_params(cfg)
            params_sh = shd.param_sharding_tree(params, mesh)
            caches = specs["caches"]
            caches_sh = _sds_tree_shardings(
                mesh, caches,
                lambda p_, l, m: shd.cache_pspec(p_, l, m, batch=b))
            tok_sh = NamedSharding(mesh, P(dpax if b > 1 else None, None))

            def serve_step(params, caches, tokens, cache_len):
                logits, _, new_caches = transformer.apply(
                    params, tokens, cfg, caches=caches, cache_len=cache_len,
                    ep_sharding=ep_sh, head_sharding=head_sh,
                    latent_sharding=lat_sh, moe_mesh=moe_mesh)
                return logits[:, -1], new_caches

            jitted = jax.jit(
                serve_step,
                in_shardings=(params_sh, caches_sh, tok_sh,
                              NamedSharding(mesh, P())),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params, caches, specs["tokens"],
                                   specs["cache_len"])
            meta = {"kind": "serve_step"}

    meta.update(arch=arch, shape=shape_name,
                mesh="2x16x16" if multi_pod else "16x16",
                chips=512 if multi_pod else 256)
    return lowered, meta, cfg, shape


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             grad_accum=None, attn_chunk=None, verbose=True) -> dict:
    t0 = time.time()
    try:
        lowered, meta, cfg, shape = lower_cell(
            arch, shape_name, multi_pod=multi_pod, grad_accum=grad_accum,
            attn_chunk=attn_chunk)
    except ValueError as e:
        if str(e).startswith("SKIP"):
            return {"arch": arch, "shape": shape_name,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "status": "skip", "reason": str(e)[6:]}
        raise
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    mem = int(ma.argument_size_in_bytes + ma.temp_size_in_bytes
              + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    costs = analyze_hlo(compiled.as_text())
    ca = compiled.cost_analysis() or {}
    terms = roofline_terms(arch, cfg, shape, meta["mesh"], meta["chips"],
                           costs, mem)
    rec = {
        **meta,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_per_device_gib": round(mem / 2**30, 3),
        "xla_cost_analysis_flops_once": ca.get("flops"),
        "hlo": costs.summary(),
        "roofline": terms.to_json(),
    }
    if verbose:
        print(json.dumps(rec["roofline"], indent=None))
        print(f"  mem/device: {rec['memory_per_device_gib']} GiB  "
              f"compile: {rec['compile_s']}s  "
              f"collectives: {costs.summary()['collectives']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = args.arch or (registry.list_archs() if args.all else [])
    shapes = args.shape or list(registry.SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if not archs:
        ap.error("pass --arch or --all")

    results = []
    if args.out and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_name)
                if key in done:
                    print(f"== cached {key}")
                    continue
                print(f"== {arch} x {shape} x {mesh_name}")
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   grad_accum=args.grad_accum,
                                   attn_chunk=args.attn_chunk)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                results.append(rec)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".",
                                exist_ok=True)
                    json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
