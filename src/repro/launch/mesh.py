"""Production mesh construction.

Function (not module-level constant) so importing never touches jax device
state.  Single pod: (16, 16) = 256 chips, axes (data, model).  Multi-pod:
(2, 16, 16) = 512 chips, axes (pod, data, model) — the 'pod' axis joins the
FSDP group (parallel/sharding.DP_AXES), so cross-pod traffic is the
parameter all-gather / gradient reduce-scatter, which tolerates the slower
inter-pod links; 'model' (TP/EP/SP) traffic stays inside a pod.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this process actually has (tests / examples).

    The model axis must tile the device count; when the request doesn't
    divide n we fall back to the largest divisor of n that is <= the
    request, so (n // model_axis, model_axis) always covers all devices.
    """
    n = len(jax.devices())
    model_axis = max(1, min(model_axis, n))
    while n % model_axis != 0:
        model_axis -= 1
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def dp_size(mesh) -> int:
    s = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            s *= mesh.shape[a]
    return s
