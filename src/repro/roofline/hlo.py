"""SPMD-partitioned HLO cost extraction (dots, collectives, loop trips).

``compiled.cost_analysis()`` counts every while-loop body exactly once, and
all of this framework's depth (layer periods, grad-accum microbatches,
attention KV chunks, SSM chunks) is expressed as ``lax.scan`` — so naive
cost_analysis under-reports a 126-layer model ~126x.  This parser walks the
partitioned module text instead:

* every computation block is parsed with its op result shapes;
* every ``while`` op's trip count is recovered from the loop-bound constant
  in its condition computation;
* dot FLOPs / dot HBM bytes / collective bytes are accumulated with the
  *product of enclosing loop trip counts* as multiplier.

All shapes in the partitioned module are already per-device, so the
resulting numbers are per-chip — exactly what the roofline terms need.

Byte conventions (ring model, per device):
  all-reduce 2x result; all-gather 1x result; reduce-scatter 1x operand;
  all-to-all 1x operand; collective-permute 1x result.
Dot memory traffic = lhs + rhs + result bytes (streaming GEMM convention;
ignores VMEM-resident reuse between fused ops — stated in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%([\w.\-]+).*?body=%([\w.\-]+)")
_CALLEE_RE = re.compile(
    r"(?:to_apply|calls|body|condition|branch_computations=\{)=?%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
# operands may carry an inline type token (newer XLA text: ``dot(f32[64,32]
# {1,0} %lhs, f32[32,16]{1,0} %rhs)``) or not (older: ``dot(%lhs, %rhs)``)
_DOT_OPERANDS = re.compile(
    r"dot\(\s*(?:[^%)]*\s)?%([\w.\-]+),\s*(?:[^%)]*\s)?%([\w.\-]+)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")


def _shape_bytes(type_str: str, dims_str: str) -> int:
    if type_str not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims_str.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[type_str]


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), m.group(2)


@dataclasses.dataclass
class HLOCosts:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    collective_bytes_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    num_whiles: int = 0
    notes: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "dot_bytes": self.dot_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": dict(self.collective_counts),
            "collective_bytes_by_kind": dict(self.collective_bytes_by_kind),
            "num_whiles": self.num_whiles,
        }


def _comp_name(line: str):
    """Computation-header line -> name, or None.

    Headers look like ``%name (params...) -> result_type {`` (params may
    contain nested parens for tuple types) or ``ENTRY %name ... {``.
    """
    s = line.strip()
    if not s.endswith("{") or "->" not in s:
        return None
    if s.startswith("ENTRY"):
        s = s[len("ENTRY"):].strip()
    if not s.startswith("%"):
        return None
    name = re.match(r"%([\w.\-]+)", s)
    return name.group(1) if name else None


def _parse_computations(text: str) -> dict:
    """name -> list of statement lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            nm = _comp_name(line)
            if nm is not None:
                cur = nm
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        comps[cur].append(line)
    return comps


def analyze_hlo(text: str) -> HLOCosts:
    comps = _parse_computations(text)
    costs = HLOCosts()

    # op name -> result shape (module-wide; HLO names are unique per module)
    shapes: dict[str, tuple] = {}
    for name, lines in comps.items():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                sh = _first_shape(m.group(2))
                if sh:
                    shapes[m.group(1)] = sh
        # computation parameters: "%p = f32[..] parameter(0)" handled above

    # while trip counts: body comp -> trips
    body_trips: dict[str, int] = {}
    cond_of_body: dict[str, str] = {}
    parent_of: dict[str, str] = {}
    for name, lines in comps.items():
        for ln in lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                cond_of_body[body] = cond
                parent_of[body] = name
                parent_of[cond] = name
                costs.num_whiles += 1
            else:
                for callee in _CALLEE_RE.findall(ln):
                    if callee in comps and callee not in parent_of:
                        parent_of[callee] = name

    for body, cond in cond_of_body.items():
        consts = [int(c) for ln in comps.get(cond, ())
                  for c in _CONST_RE.findall(ln)]
        body_trips[body] = max(consts) if consts else 1

    def multiplier(comp: str) -> int:
        mult = 1
        seen = set()
        cur = comp
        while cur in parent_of and cur not in seen:
            seen.add(cur)
            if cur in body_trips:
                mult *= body_trips[cur]
            cur = parent_of[cur]
        if cur in body_trips and cur not in seen:
            mult *= body_trips[cur]
        return mult

    coll_kinds = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

    for name, lines in comps.items():
        mult = multiplier(name)
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            rhs = m.group(2)
            res = _first_shape(rhs)
            if res is None:
                continue
            res_bytes = _shape_bytes(*res)

            if " dot(" in rhs or rhs.startswith("dot("):
                dm = _DOT_OPERANDS.search(rhs)
                cm = _LHS_CDIMS.search(rhs)
                if dm and cm:
                    lhs_shape = shapes.get(dm.group(1))
                    rhs_shape = shapes.get(dm.group(2))
                    k = 1
                    if lhs_shape:
                        dims = [int(d) for d in lhs_shape[1].split(",") if d]
                        for ci in (int(c) for c in cm.group(1).split(",") if c):
                            if ci < len(dims):
                                k *= dims[ci]
                    res_elems = 1
                    for d in res[1].split(","):
                        if d:
                            res_elems *= int(d)
                    costs.dot_flops += mult * 2.0 * res_elems * k
                    lb = _shape_bytes(*lhs_shape) if lhs_shape else 0
                    rb = _shape_bytes(*rhs_shape) if rhs_shape else 0
                    costs.dot_bytes += mult * float(lb + rb + res_bytes)
                continue

            for kind in coll_kinds:
                if f" {kind}(" in rhs or rhs.startswith(f"{kind}("):
                    if kind == "all-reduce":
                        moved = 2.0 * res_bytes
                    elif kind in ("reduce-scatter", "all-to-all"):
                        op_m = re.search(
                            rf"{kind}\(\s*(?:[^%)]*\s)?%([\w.\-]+)", rhs)
                        src = shapes.get(op_m.group(1)) if op_m else None
                        moved = float(_shape_bytes(*src)) if src else float(res_bytes)
                    else:
                        moved = float(res_bytes)
                    costs.collective_bytes += mult * moved
                    costs.collective_counts[kind] += mult
                    costs.collective_bytes_by_kind[kind] += mult * moved
                    break
    return costs
