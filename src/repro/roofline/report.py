"""Roofline terms per (arch x shape x mesh) from the compiled dry-run.

  compute    = dot_FLOPs_per_device / peak_FLOP/s
  memory     = dot_HBM_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI link bw

(per-device numbers come straight from the SPMD-partitioned HLO — see
roofline/hlo.py).  MODEL_FLOPS uses the 6·N·D convention (N = active params
for MoE) plus the attention quadratic term, giving the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs that exposes remat/padding/routing waste.
"""

from __future__ import annotations

import dataclasses
import json

from ..core.target import TPUTarget, get_target
from ..models.config import ModelConfig
from ..models.registry import ShapeSpec
from .hlo import HLOCosts


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    hlo_flops_per_device: float
    model_flops_total: float
    useful_ratio: float                  # MODEL_FLOPS / (HLO_FLOPs * chips)
    roofline_frac: float                 # useful time / bound time
    memory_per_device_bytes: int
    notes: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s*1e3:.1f} | {self.memory_s*1e3:.1f} | "
                f"{self.collective_s*1e3:.1f} | {self.dominant} | "
                f"{self.useful_ratio:.2f} | {self.roofline_frac:.2f} |")


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N_active·D + attention quadratic (paper FLOP convention)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n * tokens
        attn = (12.0 * cfg.num_layers * cfg.num_q_heads * cfg.head_dim
                * shape.seq_len ** 2 * shape.global_batch * 0.5)
        if cfg.rwkv or cfg.hybrid_period:
            frac = (1.0 / cfg.hybrid_period) if cfg.hybrid_period else 0.0
            attn *= frac
        return base + attn
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n * tokens
        attn = (4.0 * cfg.num_layers * cfg.num_q_heads * cfg.head_dim
                * shape.seq_len ** 2 * shape.global_batch * 0.5)
        if cfg.rwkv or cfg.hybrid_period:
            attn *= (1.0 / cfg.hybrid_period) if cfg.hybrid_period else 0.0
        return base + attn
    # decode: one token, attends to the whole cache
    tokens = shape.global_batch
    base = 2.0 * n * tokens
    attn_layers = cfg.num_layers
    if cfg.hybrid_period:
        attn_layers = cfg.num_layers // cfg.hybrid_period
    if cfg.rwkv:
        attn_layers = 0
    attn = (4.0 * attn_layers * cfg.num_q_heads * cfg.head_dim
            * shape.seq_len * tokens)
    return base + attn


def roofline_terms(arch: str, cfg: ModelConfig, shape: ShapeSpec,
                   mesh_name: str, chips: int, costs: HLOCosts,
                   memory_per_device: int,
                   target: TPUTarget | str = "v5e",
                   notes: str = "") -> RooflineTerms:
    t = get_target(target) if isinstance(target, str) else target
    compute = costs.dot_flops / (t.peak_bf16_tflops * 1e12)
    memory = costs.dot_bytes / (t.hbm_gbps * 1e9)
    coll = costs.collective_bytes / (t.ici_gbps * 1e9)
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = costs.dot_flops * chips
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(compute, memory, coll)
    useful_time = mf / (chips * t.peak_bf16_tflops * 1e12)
    frac = useful_time / bound if bound > 0 else 0.0
    return RooflineTerms(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        compute_s=compute, memory_s=memory, collective_s=coll,
        dominant=dominant, hlo_flops_per_device=costs.dot_flops,
        model_flops_total=mf, useful_ratio=useful, roofline_frac=frac,
        memory_per_device_bytes=memory_per_device, notes=notes)


HEADER = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| dominant | useful ratio | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|---|")
