from .hlo import HLOCosts, analyze_hlo  # noqa: F401
from .report import RooflineTerms, roofline_terms  # noqa: F401
