"""Mixture-of-Experts FFN (DeepSeek-V2 / Qwen3 / Jamba style).

Top-k routing with capacity truncation, sort-based dispatch (real
gather/scatter — NOT the one-hot-einsum dispatch, whose S^2-shaped matmuls
would pollute the roofline compute term with routing overhead), shared
experts added densely, and a load-balancing auxiliary loss.

Sharding intent (see parallel/sharding.py): expert weights are sharded over
the 'model' axis on the expert dim (expert parallelism); tokens arrive
sharded over ('pod','data').  The dispatch scatter/gather crosses the two,
which GSPMD lowers to the expert all-to-all pattern.  The baseline keeps
this implicit; EXPERIMENTS.md §Perf measures it from the dry-run HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig


def moe_init(key, cfg: ModelConfig):
    d, e = cfg.d_model, cfg.num_experts
    ff = cfg.moe_d_ff
    dt = layers.jdtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (d, e), jnp.float32),
        "we_gate": layers.dense_init(ks[1], (e, d, ff), dt),
        "we_up": layers.dense_init(ks[2], (e, d, ff), dt),
        "we_down": layers.dense_init(ks[3], (e, ff, d), dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.swiglu_init(
            ks[4], d, cfg.moe_d_ff * cfg.num_shared_experts, cfg.dtype)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def _dispatch(x, logits, e: int, k: int, cap: int):
    """Per-row top-k routing + sort-based dispatch (shared by both paths).

    Returns (xe (B,E,cap,d), slot, sorted_tok, sorted_w, keep, aux_parts).
    """
    b, t, d = x.shape
    probs = jax.nn.softmax(logits, axis=-1)                     # (B, T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (B, T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=(0, 1))                            # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    nk = t * k
    flat_exp = gate_idx.reshape(b, nk)
    flat_tok = jnp.broadcast_to(jnp.repeat(jnp.arange(t), k)[None], (b, nk))
    flat_w = gate_vals.reshape(b, nk)
    order = jnp.argsort(flat_exp, axis=1)                        # stable
    sorted_exp = jnp.take_along_axis(flat_exp, order, axis=1)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=1)
    sorted_w = jnp.take_along_axis(flat_w, order, axis=1)
    onehot = jax.nn.one_hot(sorted_exp, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                              sorted_exp[..., None], axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, sorted_exp * cap + pos, e * cap)
    xt_sorted = jnp.take_along_axis(x, sorted_tok[..., None], axis=1)
    xe = jnp.zeros((b, e * cap + 1, d), x.dtype)
    xe = jax.vmap(lambda buf, s, v: buf.at[s].set(v, mode="drop"))(
        xe, slot, xt_sorted)
    return xe[:, :-1].reshape(b, e, cap, d), slot, sorted_tok, sorted_w, \
        keep, (me, ce)


def moe_apply_shardmap(params, x, *, cfg: ModelConfig, mesh, dp_axes,
                       model_axis: str = "model"):
    """Expert-parallel MoE with an explicit shard_map interior.

    Everything data-dependent (routing, sort, scatter/gather) runs *local*
    to each device; each 'model' shard computes only its E/TP experts and
    combines its partial outputs locally; one psum over 'model' finishes
    the combine.  Per layer-microbatch traffic = 2 x (B_loc, T, d) — vs the
    GSPMD path's deferred-AR-through-gather pattern (~24x more on qwen3,
    EXPERIMENTS.md §Perf iteration B2).
    """
    from jax.sharding import PartitionSpec as P

    e, k = cfg.num_experts, cfg.top_k
    tp = mesh.shape[model_axis]
    e_loc = e // tp
    b, t, d = x.shape
    cap = _capacity(t, cfg)

    expert_keys = ("we_gate", "we_up", "we_down")
    p_specs = {nm: (P(model_axis, None, None) if nm in expert_keys else
                    jax.tree.map(lambda _: P(), params[nm])
                    if isinstance(params[nm], dict) else P())
               for nm in params}
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # pragma: no cover - version fallback
        from jax.experimental.shard_map import shard_map

    def local(p, x_loc):
        logits = jnp.einsum("btd,de->bte", x_loc.astype(jnp.float32),
                            p["router"])
        xe, slot, sorted_tok, sorted_w, keep, (me, ce) = _dispatch(
            x_loc, logits, e, k, cap)
        my = jax.lax.axis_index(model_axis)
        xe_mine = jax.lax.dynamic_slice_in_dim(xe, my * e_loc, e_loc, 1)
        g = jnp.einsum("becd,edf->becf", xe_mine, p["we_gate"])
        u = jnp.einsum("becd,edf->becf", xe_mine, p["we_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        ye_mine = jnp.einsum("becf,efd->becd", h, p["we_down"])
        # place my experts' outputs back at their global slot range and
        # combine locally; other shards' slots read the zero padding row
        bl = x_loc.shape[0]
        ye_flat = jnp.zeros((bl, e * cap + 1, d), ye_mine.dtype)
        ye_flat = jax.lax.dynamic_update_slice_in_dim(
            ye_flat, ye_mine.reshape(bl, e_loc * cap, d), my * e_loc * cap,
            axis=1)
        y_sorted = jnp.take_along_axis(ye_flat, slot[..., None], axis=1)
        y_sorted = y_sorted * (sorted_w * keep).astype(
            y_sorted.dtype)[..., None]
        out = jnp.zeros((bl, t, d), x_loc.dtype)
        out = jax.vmap(lambda buf, s, v: buf.at[s].add(v))(
            out, sorted_tok, y_sorted)
        out = jax.lax.psum(out, model_axis)        # EP combine
        aux = cfg.aux_loss_coef * e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp_axes)
        if cfg.num_shared_experts:
            out = out + layers.swiglu(p["shared"], x_loc)
        return out, aux

    kwargs = dict(mesh=mesh, in_specs=(p_specs, P(dp_axes, None, None)),
                  out_specs=(P(dp_axes, None, None), P()))
    try:
        mapped = shard_map(local, check_vma=False, **kwargs)
    except TypeError:  # pragma: no cover - older jax spelling
        mapped = shard_map(local, check_rep=False, **kwargs)
    return mapped(params, x)


def moe_apply(params, x, *, cfg: ModelConfig, ep_sharding=None):
    """x: (B, T, d) -> (out, aux_loss).

    Dispatch is **per batch row**: every gather/scatter and the
    position-within-expert cumsum is batched over B (the data-parallel
    axis), so routing never crosses data shards.  The only cross-device
    movement is the (B, E, C, d) expert-buffer reshard from B-sharded to
    (B x E)-sharded — the expert-parallel all-to-all — which
    ``ep_sharding`` pins explicitly.  (The earlier global-token dispatch
    let GSPMD all-gather the whole token stream per MoE layer: a measured
    ~9x collective blow-up on qwen3-moe, EXPERIMENTS.md §Perf.)
    """
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = _capacity(t, cfg)

    tok_sharding = None
    if ep_sharding is not None:
        from jax.sharding import PartitionSpec as _P
        tok_sharding = _P(ep_sharding[0], None, None)

    def tokc(v):
        # pin token-space gathers/scatters to dp-only sharding: without
        # this GSPMD partitions take_along_axis over 'model' and
        # all-reduces the (T*k, d) gather output every MoE layer (a
        # measured 4.8 TB/step on qwen3-moe, EXPERIMENTS.md §Perf)
        if tok_sharding is None:
            return v
        return jax.lax.with_sharding_constraint(v, tok_sharding)

    x = tokc(x)
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (B, T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (B, T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balancing aux loss (Switch-style) ----------------------------
    me = jnp.mean(probs, axis=(0, 1))                            # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = cfg.aux_loss_coef * e * jnp.sum(me * ce)

    # ---- per-row sort-based dispatch ----------------------------------------
    nk = t * k
    flat_exp = gate_idx.reshape(b, nk)                           # (B, T*k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(t), k)[None], (b, nk))
    flat_w = gate_vals.reshape(b, nk)
    order = jnp.argsort(flat_exp, axis=1)                        # stable
    sorted_exp = jnp.take_along_axis(flat_exp, order, axis=1)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=1)
    sorted_w = jnp.take_along_axis(flat_w, order, axis=1)
    onehot = jax.nn.one_hot(sorted_exp, e, dtype=jnp.int32)      # (B, T*k, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - 1,
                              sorted_exp[..., None], axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, sorted_exp * cap + pos, e * cap)      # drop -> pad

    # gather tokens into per-row (E*cap, d) expert buffers (+1 padding row)
    xt_sorted = tokc(jnp.take_along_axis(x, sorted_tok[..., None], axis=1))
    xe = jnp.zeros((b, e * cap + 1, d), x.dtype)
    xe = jax.vmap(lambda buf, s, v: buf.at[s].set(v, mode="drop"))(
        xe, slot, xt_sorted)
    xe = xe[:, :-1].reshape(b, e, cap, d)
    if ep_sharding is not None:
        xe = jax.lax.with_sharding_constraint(xe, ep_sharding)

    # ---- expert FFN (SwiGLU), batched over (row, expert) --------------------
    g = jnp.einsum("becd,edf->becf", xe, params["we_gate"])
    u = jnp.einsum("becd,edf->becf", xe, params["we_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("becf,efd->becd", h, params["we_down"])
    if ep_sharding is not None:
        ye = jax.lax.with_sharding_constraint(ye, ep_sharding)

    # ---- combine back ---------------------------------------------------------
    ye_flat = jnp.concatenate(
        [ye.reshape(b, e * cap, d),
         jnp.zeros((b, 1, d), ye.dtype)], axis=1)
    y_sorted = tokc(jnp.take_along_axis(ye_flat, slot[..., None], axis=1))
    y_sorted = y_sorted * (sorted_w * keep).astype(y_sorted.dtype)[..., None]
    out = jnp.zeros((b, t, d), x.dtype)
    out = tokc(jax.vmap(lambda buf, s, v: buf.at[s].add(v))(
        out, sorted_tok, y_sorted))

    if cfg.num_shared_experts:
        out = out + layers.swiglu(params["shared"], x)
    return out, aux
