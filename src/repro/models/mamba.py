"""Mamba (selective SSM) block for the Jamba hybrid — chunked scan form.

TPU adaptation: the recurrence h_t = dA_t * h_{t-1} + dBx_t is diagonal in
the state dim, so it lowers to a `lax.scan` over *chunks* with the
(d_inner, state) carry in f32 — sequence stays unsharded for SSM layers,
d_inner is the tensor-parallel axis (DESIGN.md §3.1).  Within a chunk the
pointwise recurrence runs as an associative scan over the chunk axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    s = cfg.mamba_state
    dt_rank = max(16, d // 16)
    dt = layers.jdtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    # S4D-real initialisation for A
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, s + 1, dtype=jnp.float32),
                                      (di, s)))
    return {
        "w_in": layers.dense_init(ks[0], (d, 2 * di), dt),
        "conv": layers.dense_init(ks[1], (cfg.mamba_conv, di), dt, scale=1.0),
        "w_x_dbc": layers.dense_init(ks[2], (di, dt_rank + 2 * s), dt),
        "w_dt": layers.dense_init(ks[3], (dt_rank, di), dt),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": a_init,
        "D": jnp.ones((di,), jnp.float32),
        "w_out": layers.dense_init(ks[4], (di, d), dt,
                                   scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }


def _chunked_ssm(dt, A, bmat, xi, C, chunk: int):
    """h_t = dA_t h_{t-1} + dBx_t ; y_t = <h_t, C_t>.

    dt/xi: (B, T, Di), A: (Di, S), bmat/C: (B, T, S).  The (Di, S)-wide
    discretised tensors dA/dBx are materialised only per *chunk* inside the
    scan — the full-sequence (B, T, Di, S) tensor would be ~4 GiB/device at
    jamba train_4k scale.
    """
    b, t, di = dt.shape
    s = A.shape[1]
    nc = t // chunk
    resh = lambda a: a.reshape(b, nc, chunk, *a.shape[2:]).swapaxes(0, 1)
    dt_c, b_c, x_c, c_c = resh(dt), resh(bmat), resh(xi), resh(C)

    def chunk_step(h0, xs):
        dtk, bk, xk, ck = xs                 # (B, chunk, ...)
        da = jnp.exp(dtk[..., None] * A[None, None])          # (B,c,Di,S)
        dbx = (dtk * xk)[..., None] * bk[:, :, None, :]

        def combine(a, b_):
            # (A1, B1) then (A2, B2): h -> A2 (A1 h + B1) + B2
            return a[0] * b_[0], b_[0] * a[1] + b_[1]

        aa, bb = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h = aa * h0[:, None] + bb            # (B, chunk, Di, S)
        y = jnp.einsum("bcds,bcs->bcd", h, ck)
        return h[:, -1], y

    h0 = jnp.zeros((b, di, s), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (dt_c, b_c, x_c, c_c))
    return ys.swapaxes(0, 1).reshape(b, t, di)


def mamba_apply(params, x, *, cfg: ModelConfig, chunk: int = 256,
                state=None):
    """x: (B, T, d).  ``state``: optional (conv_tail, h) for decode.

    Training path: chunked scan over the full sequence (state=None).
    Decode path (T small): sequential update of the carried state.
    """
    b, t, d = x.shape
    di = cfg.mamba_expand * d
    s = cfg.mamba_state
    dt_rank = params["w_dt"].shape[0]
    xz = jnp.einsum("btd,de->bte", x, params["w_in"])
    xi, z = xz[..., :di], xz[..., di:]

    # depthwise causal conv along T
    kw = params["conv"].shape[0]
    if state is not None:
        conv_in = jnp.concatenate([state["conv"], xi], axis=1)
    else:
        conv_in = jnp.pad(xi, ((0, 0), (kw - 1, 0), (0, 0)))
    xi = sum(conv_in[:, i:i + t] * params["conv"][i][None, None]
             for i in range(kw))
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(x.dtype)

    dbc = jnp.einsum("bte,ef->btf", xi, params["w_x_dbc"])
    dt_in, bmat, cmat = (dbc[..., :dt_rank],
                         dbc[..., dt_rank:dt_rank + s],
                         dbc[..., dt_rank + s:])
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_in, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                     # (Di, S), negative
    xif = xi.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    new_state = None
    if state is not None:
        dA = jnp.exp(dt[..., None] * A[None, None])   # (B, T<=small, Di, S)
        dBx = (dt * xif)[..., None] * bf[:, :, None, :]

        def step(h, xs):
            da, dbx, c = xs
            h = da * h + dbx
            return h, jnp.einsum("bds,bs->bd", h, c)
        h_last, ys = jax.lax.scan(
            step, state["h"],
            (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3),
             cf.transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2)
        new_state = {"conv": conv_in[:, -(kw - 1):], "h": h_last}
    else:
        tpad = (-t) % chunk
        if tpad:
            dt = jnp.pad(dt, ((0, 0), (0, tpad), (0, 0)))
            xif = jnp.pad(xif, ((0, 0), (0, tpad), (0, 0)))
            bf = jnp.pad(bf, ((0, 0), (0, tpad), (0, 0)))
            cf = jnp.pad(cf, ((0, 0), (0, tpad), (0, 0)))
        y = _chunked_ssm(dt, A, bf, xif, cf, chunk)[:, :t]

    y = y + params["D"][None, None] * xi.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("btd,de->bte", y.astype(x.dtype), params["w_out"])
    return out, new_state


def mamba_init_state(cfg: ModelConfig, batch: int):
    di = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.mamba_conv - 1, di),
                          layers.jdtype(cfg.dtype)),
        "h": jnp.zeros((batch, di, cfg.mamba_state), jnp.float32),
    }
