"""Model assembly: period-structured scan-over-layers for all families.

The layer stack is organised as ``num_periods`` repetitions of a short
*period* of sub-layers (MaxText-style stacked params + ``lax.scan`` over
periods, so HLO size is O(period length), not O(depth)):

  dense/moe/audio : period = [attn]                    (period length 1)
  hybrid (jamba)  : period = [mamba]*7 + [attn]        (1:7 interleave)
  vlm             : period = [self]*4 + [cross]        (cross-attn every 5th)
  ssm (rwkv6)     : period = [rwkv]

Every sub-layer is pre-norm residual: x += mix(norm(x)); x += ffn(norm(x)),
where ``mix`` is attention / Mamba / RWKV time-mix and ``ffn`` is SwiGLU,
MoE (on sub-positions where ``(s % moe_every) == moe_every-1``) or RWKV
channel-mix.  ``first_k_dense`` leading layers (DeepSeek-V2's dense first
layer) are kept outside the scan with their own params.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import attention, layers, mamba, moe, rwkv
from .config import ModelConfig


# --------------------------------------------------------------------------
# period structure
# --------------------------------------------------------------------------

def period_spec(cfg: ModelConfig) -> tuple[list[str], int]:
    """Returns (sub-layer kinds, num_periods)."""
    if cfg.rwkv:
        return ["rwkv"], cfg.num_layers
    if cfg.hybrid_period:
        p = cfg.hybrid_period
        assert (cfg.num_layers - cfg.first_k_dense) % p == 0
        return ["mamba"] * (p - 1) + ["attn"], \
            (cfg.num_layers - cfg.first_k_dense) // p
    if cfg.cross_attn_period:
        p = cfg.cross_attn_period
        assert cfg.num_layers % p == 0
        return ["self"] * (p - 1) + ["cross"], cfg.num_layers // p
    return ["attn"], cfg.num_layers - cfg.first_k_dense


def _is_moe(cfg: ModelConfig, sub_idx: int) -> bool:
    if not cfg.moe:
        return False
    return (sub_idx % cfg.moe_every) == (cfg.moe_every - 1)


# --------------------------------------------------------------------------
# sub-layer init / apply
# --------------------------------------------------------------------------

def _sublayer_init(key, cfg: ModelConfig, kind: str, use_moe: bool):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"norm1": layers.rmsnorm_init(d, cfg.dtype)}
    if kind in ("attn", "self"):
        p["mix"] = (attention.mla_init(ks[0], cfg) if cfg.mla
                    else attention.attn_init(ks[0], cfg))
    elif kind == "cross":
        p["mix"] = attention.attn_init(ks[0], cfg, cross=True)
    elif kind == "mamba":
        p["mix"] = mamba.mamba_init(ks[0], cfg)
    elif kind == "rwkv":
        p["mix"] = rwkv.rwkv_init(ks[0], cfg)
        p["norm2"] = layers.rmsnorm_init(d, cfg.dtype)
        return p  # rwkv carries its own channel-mix inside p["mix"]
    else:
        raise ValueError(kind)
    p["norm2"] = layers.rmsnorm_init(d, cfg.dtype)
    p["ffn"] = (moe.moe_init(ks[1], cfg) if use_moe
                else layers.swiglu_init(ks[1], d, cfg.d_ff, cfg.dtype))
    return p


def _sublayer_apply(p, x, kind: str, use_moe: bool, cfg: ModelConfig, ctx):
    """ctx: dict(positions, vision, cache (this sub-layer's), cache_len).
    Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.rmsnorm(x, p["norm1"], cfg.norm_eps)
    new_cache = None
    if kind == "rwkv":
        o, new_cache = rwkv.rwkv_time_mix(
            p["mix"], h, cfg=cfg, state=ctx.get("cache"),
            use_pallas=cfg.attn_impl == "tl_pallas")
        x = x + o
        h2 = layers.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + rwkv.rwkv_channel_mix(p["mix"], h2)
        return x, new_cache, aux
    if kind in ("attn", "self"):
        cache = ctx.get("cache")
        if cache is not None:
            cache = dict(cache, len=ctx["cache_len"])
        if cfg.mla:
            o, new_cache = attention.mla_apply(
                p["mix"], h, cfg=cfg, positions=ctx.get("positions"),
                cache=cache, head_sharding=ctx.get("head_sharding"),
                latent_sharding=ctx.get("latent_sharding"),
                kv_bucket=ctx.get("kv_bucket"),
                block_tables=ctx.get("block_tables"),
                page_size=ctx.get("page_size"),
                num_splits=ctx.get("num_splits"),
                chunk_valid=ctx.get("chunk_valid"),
                verify=bool(ctx.get("verify")), tp=ctx.get("tp"))
        else:
            o, new_cache = attention.attn_apply(
                p["mix"], h, cfg=cfg, positions=ctx.get("positions"),
                cache=cache, head_sharding=ctx.get("head_sharding"),
                kv_bucket=ctx.get("kv_bucket"),
                block_tables=ctx.get("block_tables"),
                page_size=ctx.get("page_size"),
                num_splits=ctx.get("num_splits"),
                chunk_valid=ctx.get("chunk_valid"),
                verify=bool(ctx.get("verify")), tp=ctx.get("tp"))
        if new_cache is not None:
            new_cache.pop("len", None)  # length tracked by the caller
        tp = ctx.get("tp")
        if tp is not None and kind in ("attn", "self") \
                and tp.plan in ("kv", "q") and tp.size > 1:
            # head-sharded wo: each shard contracted its head slice — the
            # residual contribution is a partial sum over the model axis
            o = jax.lax.psum(o, tp.axis)
    elif kind == "cross":
        o, new_cache = attention.cross_attn_apply(
            p["mix"], h, cfg=cfg, vision=ctx.get("vision"),
            cache=ctx.get("cache"))
    elif kind == "mamba":
        o, new_cache = mamba.mamba_apply(p["mix"], h, cfg=cfg,
                                         state=ctx.get("cache"))
    else:
        raise ValueError(kind)
    x = x + o
    h2 = layers.rmsnorm(x, p["norm2"], cfg.norm_eps)
    if use_moe:
        mm = ctx.get("moe_mesh")
        if mm is not None:
            f, aux = moe.moe_apply_shardmap(p["ffn"], h2, cfg=cfg,
                                            mesh=mm[0], dp_axes=mm[1])
        else:
            f, aux = moe.moe_apply(p["ffn"], h2, cfg=cfg,
                                   ep_sharding=ctx.get("ep_sharding"))
    else:
        f = layers.swiglu(p["ffn"], h2)
        tp = ctx.get("tp")
        if tp is not None and tp.ffn and tp.size > 1:
            # ff-sharded w_down: partial sum over the model axis
            f = jax.lax.psum(f, tp.axis)
    return x + f, new_cache, aux


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    kinds, nper = period_spec(cfg)
    keys = jax.random.split(key, 8)
    params = {
        "embed": layers.embedding_init(keys[0], cfg.vocab_size, cfg.d_model,
                                       cfg.dtype),
        "final_norm": layers.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), layers.jdtype(cfg.dtype))
    if cfg.cross_attn_period and cfg.vision_d:
        pass  # cross-attn wk/wv already take vision_d input

    # leading dense layers outside the scan
    if cfg.first_k_dense:
        fk = []
        for i in range(cfg.first_k_dense):
            fk.append(_sublayer_init(
                jax.random.fold_in(keys[2], i), cfg,
                "attn" if not cfg.rwkv else "rwkv", use_moe=False))
        params["first"] = fk

    # stacked period params: params["blocks"][f"sub{i}"] has leading nper dim
    blocks = {}
    for s, kind in enumerate(kinds):
        def one(pi, s=s, kind=kind):
            return _sublayer_init(
                jax.random.fold_in(jax.random.fold_in(keys[3], s), pi),
                cfg, kind, _is_moe(cfg, s))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[one(pi) for pi in range(nper)])
        blocks[f"sub{s}"] = stacked
    params["blocks"] = blocks
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def apply(params, tokens, cfg: ModelConfig, *, vision_embeds=None,
          caches=None, cache_len=None, positions=None, kv_bucket=None,
          block_tables=None, page_size=None, num_splits=None,
          chunk_valid=None, verify=False, act_sharding=None,
          ep_sharding=None, head_sharding=None, latent_sharding=None,
          moe_mesh=None, tp=None):
    """tokens: (B, T) int32 -> logits (B, T, V) f32.

    ``caches``: pytree from :func:`init_caches` for decode; ``cache_len``
    counts valid cache entries — a python int, a traced scalar, or a
    per-request (B,) vector (length-heterogeneous serving batches; RoPE
    positions then differ per row).  ``kv_bucket`` (static int) bounds how
    many cache entries attention *reads*: the serving engine passes a
    power-of-two bucket ≥ cache_len+T so decode compiles once per bucket,
    not once per step.  Returns (logits, aux, new_caches).

    ``block_tables`` + ``page_size``: paged cache — the attention caches
    in ``caches`` are then page pools (see ``init_caches(paged=True)``)
    and ``block_tables`` (B, Tmax) int32 maps each row's logical pages to
    physical pool pages, shared by every layer.  T == 1 decodes; T > 1
    runs one chunk of chunked prefill (K/V scattered straight into the
    pages, causal attention against the history through the table).
    ``chunk_valid`` (optional (B,) runtime vector) is the count of real
    tokens in a padded prefill chunk — every attention layer's page
    scatter masks the pad tail so it never lands in the pools.

    ``num_splits`` (static): split-KV decode partition count for every
    attention layer — None lets the reasoning heuristic choose per layer
    geometry, 1 forces the sequential KV pass, >1 forces that many
    (clamped) splits.  Shape-relevant: callers jitting ``apply`` must key
    their cache on it alongside ``kv_bucket``.

    ``verify`` (static bool): the T > 1 paged chunk is a speculative-
    decode draft window — attention runs the ``verify`` TL mode (chunk
    tiling + optional split-KV; ``num_splits`` applies) and the returned
    per-position logits are the draft-acceptance oracle.  Semantically
    identical to chunked prefill of the same tokens; only the
    work-partitioning differs.

    ``tp``: tensor-parallel serving context (``parallel.sharding.ServeTP``)
    — only meaningful when ``apply`` runs *inside* ``shard_map`` on a
    device mesh: attention params are per-shard head slices ('kv'/'q'
    plans; their wo contribution psums over the axis), MLA sequence-splits
    its replicated latent cache ('seq' plan), and a sharded dense FFN
    psums its w_down contraction.  ``None`` (the default) is the ordinary
    single-device/GSPMD path.

    ``act_sharding``: optional PartitionSpec for the (B, T, d) residual
    stream.  Constraining it *inside* the period scan is what shards the
    per-period saved residuals — with sequence parallelism
    (P(dp, 'model', None)) the 126-period residual stack of llama3-405b
    drops 16x (EXPERIMENTS.md §Perf).
    """
    kinds, nper = period_spec(cfg)
    b, t = tokens.shape
    x = layers.embed(params["embed"], tokens)

    # Megatron-style sequence parallelism at period granularity: the scan
    # carry (= the per-period saved residual) lives sequence-sharded over
    # 'model'; inside a period the activations are gathered back to full
    # sequence so GSPMD contracts against the model-sharded weights instead
    # of all-gathering them (a measured 10x collective difference on
    # llama3-405b — EXPERIMENTS.md §Perf).
    compute_sharding = None
    if act_sharding is not None:
        from jax.sharding import PartitionSpec as _P
        compute_sharding = _P(act_sharding[0], None, None)

    def constrain(v, spec=None):
        spec = spec if spec is not None else act_sharding
        if spec is not None and v.ndim == 3 and v.shape[1] == t:
            return jax.lax.with_sharding_constraint(v, spec)
        return v

    x = constrain(x)
    if positions is None:
        start = cache_len if cache_len is not None else 0
        if jnp.ndim(start) == 1:   # per-request lengths -> (B, T) positions
            positions = start[:, None] + jnp.arange(t)[None, :]
        else:
            positions = start + jnp.arange(t)

    aux_total = jnp.zeros((), jnp.float32)

    clen = cache_len if cache_len is not None else 0

    def make_ctx(cache):
        return {"positions": positions, "vision": vision_embeds,
                "cache": cache, "cache_len": clen,
                "kv_bucket": kv_bucket, "num_splits": num_splits,
                "block_tables": block_tables, "page_size": page_size,
                "chunk_valid": chunk_valid, "verify": verify,
                "ep_sharding": ep_sharding,
                "head_sharding": head_sharding,
                "latent_sharding": latent_sharding,
                "moe_mesh": moe_mesh, "tp": tp}

    # leading dense layers
    new_first_caches = []
    if cfg.first_k_dense:
        for i, p in enumerate(params["first"]):
            cache = caches["first"][i] if caches else None
            x, nc, aux = _sublayer_apply(
                p, x, "attn" if not cfg.rwkv else "rwkv", False, cfg,
                make_ctx(cache))
            new_first_caches.append(nc)
            aux_total += aux

    # scanned periods
    def period_body(carry, xs):
        x, aux_acc = carry
        block_params, period_caches = xs
        new_caches = {}
        # gather sequence for compute (weights stay model-sharded) ...
        x = constrain(x, compute_sharding)
        for s, kind in enumerate(kinds):
            cache = period_caches.get(f"sub{s}") if period_caches else None
            x, nc, aux = _sublayer_apply(
                block_params[f"sub{s}"], x, kind, _is_moe(cfg, s), cfg,
                make_ctx(cache))
            if nc is not None:
                new_caches[f"sub{s}"] = nc
            aux_acc = aux_acc + aux
        # ... and reduce-scatter the carry back to sequence-sharded
        x = constrain(x)
        return (x, aux_acc), new_caches

    body = period_body
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots_nobatch"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(period_body, policy=policy)

    period_caches = caches["blocks"] if caches else {}
    groups = cfg.remat_scan_groups
    if groups and caches is None and nper % groups == 0:
        # sqrt-depth remat: only G outer carries + nper/G inner carries are
        # saved (the inner scan is itself checkpointed)
        grouped = jax.tree.map(
            lambda a: a.reshape(groups, nper // groups, *a.shape[1:]),
            params["blocks"])

        def group_body(carry, group_params):
            (xg, auxg), _ = jax.lax.scan(body, carry, (group_params, {}))
            return (xg, auxg), None

        (x, aux_total), _ = jax.lax.scan(
            jax.checkpoint(group_body,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (x, aux_total), grouped)
        new_block_caches = {}
    else:
        (x, aux_total), new_block_caches = jax.lax.scan(
            body, (x, aux_total), (params["blocks"], period_caches))

    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = jnp.dot(x, params["lm_head"],
                         preferred_element_type=jnp.float32)

    new_caches = None
    if caches is not None:
        new_caches = {"blocks": new_block_caches}
        if cfg.first_k_dense:
            new_caches["first"] = new_first_caches
    return logits, aux_total, new_caches


# --------------------------------------------------------------------------
# KV / state caches for decode
# --------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int, *,
                paged: bool = False, page_size: int = 64,
                num_pages: Optional[int] = None, kv_quant: bool = False):
    """Decode caches, stacked over periods for the scanned blocks.

    Cache entries do NOT carry the running length — pass ``cache_len`` to
    :func:`apply`; per-sub-layer dicts get it injected there.

    ``paged=True`` replaces the dense per-row attention caches with page
    *pools* shared across the batch — ``(num_pages, Hkv, page_size, D)``
    per KV tensor (``(num_pages, page_size, R+Rr)`` for MLA) — addressed
    through the ``block_tables`` argument of :func:`apply`.  HBM is then
    reserved per *pool*, not per ``batch x max_len`` slot; recurrent /
    cross-attention state stays per-row (it is O(1) in sequence length).

    ``kv_quant=True`` (paged only) stores the pools as symmetric int8
    with one f32 absmax scale per page — extra ``(num_pages,)`` leaves
    ``"ks"``/``"vs"`` (``"cs"`` for MLA) next to the pools.  The
    attention layer quantizes on scatter and dequantizes per page inside
    the kernel's KV loop; Q/O/compute dtypes are unchanged, so the cache
    footprint drops ~2x (bf16) / ~4x (f32) for a bounded dequant error.
    """
    kinds, nper = period_spec(cfg)
    dt = layers.jdtype(cfg.dtype)
    if paged and num_pages is None:
        raise ValueError("paged caches need num_pages (the pool capacity)")
    if kv_quant and not paged:
        raise ValueError("kv_quant is a paged-pool contract (per-page "
                         "absmax scales); pass paged=True")

    def one_cache(kind):
        if kind == "cross":
            return {"k": jnp.zeros((batch, cfg.num_kv_heads,
                                    cfg.num_patches, cfg.head_dim), dt),
                    "v": jnp.zeros((batch, cfg.num_kv_heads,
                                    cfg.num_patches, cfg.head_dim), dt)}
        if kind in ("attn", "self"):
            if paged:
                pdt = jnp.int8 if kv_quant else dt
                if cfg.mla:
                    c = {"c": jnp.zeros(
                        (num_pages, page_size,
                         cfg.kv_lora_rank + cfg.rope_head_dim), pdt)}
                    if kv_quant:
                        c["cs"] = jnp.zeros((num_pages,), jnp.float32)
                    return c
                c = {"k": jnp.zeros((num_pages, cfg.num_kv_heads,
                                     page_size, cfg.head_dim), pdt),
                     "v": jnp.zeros((num_pages, cfg.num_kv_heads,
                                     page_size, cfg.head_dim), pdt)}
                if kv_quant:
                    c["ks"] = jnp.zeros((num_pages,), jnp.float32)
                    c["vs"] = jnp.zeros((num_pages,), jnp.float32)
                return c
            if cfg.mla:
                return {"c": jnp.zeros(
                    (batch, max_len, cfg.kv_lora_rank + cfg.rope_head_dim), dt)}
            return {"k": jnp.zeros((batch, cfg.num_kv_heads, max_len,
                                    cfg.head_dim), dt),
                    "v": jnp.zeros((batch, cfg.num_kv_heads, max_len,
                                    cfg.head_dim), dt)}
        if kind == "mamba":
            return mamba.mamba_init_state(cfg, batch)
        if kind == "rwkv":
            return rwkv.rwkv_init_state(cfg, batch)
        return None

    blocks = {}
    for s, kind in enumerate(kinds):
        c = one_cache(kind)
        if c is not None:
            blocks[f"sub{s}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (nper,) + a.shape).copy(), c)
    caches = {"blocks": blocks}
    if cfg.first_k_dense:
        caches["first"] = [one_cache("attn" if not cfg.rwkv else "rwkv")
                           for _ in range(cfg.first_k_dense)]
    return caches


# --------------------------------------------------------------------------
# losses / steps (model-level; the train package adds optimizer + sharding)
# --------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ModelConfig, vision_embeds=None,
            act_sharding=None, ep_sharding=None, head_sharding=None,
            latent_sharding=None, moe_mesh=None):
    logits, aux, _ = apply(params, batch["tokens"], cfg,
                           vision_embeds=vision_embeds,
                           act_sharding=act_sharding,
                           ep_sharding=ep_sharding,
                           head_sharding=head_sharding,
                           latent_sharding=latent_sharding,
                           moe_mesh=moe_mesh)
    loss = layers.softmax_xent(logits, batch["labels"],
                               batch.get("loss_mask"))
    return loss + aux, {"xent": loss, "aux": aux}
