"""RWKV-6 ("Finch") block — attention-free, data-dependent decay.

The paper's FlashAttention-generation technique is inapplicable here
(DESIGN.md §Arch-applicability); the time-mix recurrence uses the chunked
linear-scan formulation — as the TL-style Pallas kernel
(``kernels/linear_scan.py``) on TPU/interpret, or the identical math in
jnp (``_chunked_jnp``) on the XLA compile path used by dry-runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .config import ModelConfig


def rwkv_init(key, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ff = cfg.d_ff
    dt = layers.jdtype(cfg.dtype)
    ks = jax.random.split(key, 12)
    lora = max(32, d // 32)
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),      # shift mixing for r,k,v,w,g
        "w_r": layers.dense_init(ks[0], (d, d), dt),
        "w_k": layers.dense_init(ks[1], (d, d), dt),
        "w_v": layers.dense_init(ks[2], (d, d), dt),
        "w_g": layers.dense_init(ks[3], (d, d), dt),
        "w_o": layers.dense_init(ks[4], (d, d), dt,
                                 scale=1.0 / (2 * cfg.num_layers) ** 0.5),
        # data-dependent decay LoRA: w_t = base + tanh(x A) B
        "decay_base": -6.0 * jnp.ones((d,), jnp.float32),
        "decay_A": layers.dense_init(ks[5], (d, lora), dt),
        "decay_B": layers.dense_init(ks[6], (lora, d), dt),
        "u": layers.dense_init(ks[7], (h, hd), jnp.float32, scale=8.0),
        "ln_x": layers.rmsnorm_init(d, cfg.dtype),
        # channel-mix
        "cm_k": layers.dense_init(ks[8], (d, ff), dt),
        "cm_v": layers.dense_init(ks[9], (ff, d), dt),
        "cm_r": layers.dense_init(ks[10], (d, d), dt),
    }


def _token_shift(x, mix, prev=None):
    """x: (B,T,d); mix: (d,). returns mix*x_{t-1} + (1-mix)*x_t."""
    if prev is None:
        prev_x = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev_x = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return x + mix.astype(x.dtype) * (prev_x - x)


def _chunked_jnp(r, k, v, w, u, chunk: int):
    """Same math as kernels/linear_scan.py, as XLA scan over chunks."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    nc = t // chunk
    rs = r.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    ks_ = k.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, h, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    ws = w.reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)

    def step(S, xs):
        rc, kc, vc, wc = [a.astype(jnp.float32) for a in xs]
        neg_ew = -jnp.exp(wc)
        c_inc = jnp.cumsum(neg_ew, axis=-2)
        c_prev = c_inc - neg_ew
        c_last = c_inc[..., -1:, :]
        r_dec = rc * jnp.exp(c_prev)
        k_grow = kc * jnp.exp(-c_inc)
        k_tail = kc * jnp.exp(c_last - c_inc)
        a = jnp.einsum("bhld,bhmd->bhlm", r_dec, k_grow)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
        a = jnp.where(tri, a, 0.0)
        diag = jnp.sum(rc * (u[None, :, None, :] * kc), axis=-1)
        o = jnp.einsum("bhlm,bhmd->bhld", a, vc)
        o += diag[..., None] * vc
        o += jnp.einsum("bhld,bhdv->bhlv", r_dec, S)
        S = jnp.exp(c_last).swapaxes(-1, -2) * S + \
            jnp.einsum("bhld,bhlv->bhdv", k_tail, vc)
        return S, o

    S0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    S_last, os = jax.lax.scan(step, S0, (rs, ks_, vs, ws))
    return os.transpose(1, 2, 0, 3, 4).reshape(b, h, t, dv), S_last


def rwkv_time_mix(params, x, *, cfg: ModelConfig, chunk: int = 64,
                  state=None, use_pallas: bool = False):
    b, t, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    mu = params["mu"]
    xr = _token_shift(x, mu[0], state["shift"] if state else None)
    xk = _token_shift(x, mu[1], state["shift"] if state else None)
    xv = _token_shift(x, mu[2], state["shift"] if state else None)
    xw = _token_shift(x, mu[3], state["shift"] if state else None)
    xg = _token_shift(x, mu[4], state["shift"] if state else None)

    r = jnp.dot(xr, params["w_r"]).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = jnp.dot(xk, params["w_k"]).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = jnp.dot(xv, params["w_v"]).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    g = jax.nn.silu(jnp.dot(xg, params["w_g"]).astype(jnp.float32))
    w = params["decay_base"].astype(jnp.float32) + jnp.dot(
        jnp.tanh(jnp.dot(xw, params["decay_A"]).astype(jnp.float32)),
        params["decay_B"].astype(jnp.float32))
    w = w.reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    new_state = None
    if state is not None:
        # sequential decode update
        decay = jnp.exp(-jnp.exp(w.astype(jnp.float32)))

        def step(S, xs):
            rt, kt, vt, dt_ = xs            # (B,H,Dk) each
            kv = kt[..., :, None] * vt[..., None, :]
            ot = jnp.einsum("bhk,bhkv->bhv",
                            rt, S + params["u"][None, :, :, None] * kv)
            S = dt_[..., None] * S + kv
            return S, ot
        S, os = jax.lax.scan(
            step, state["S"],
            (r.transpose(2, 0, 1, 3).astype(jnp.float32),
             k.transpose(2, 0, 1, 3).astype(jnp.float32),
             v.transpose(2, 0, 1, 3).astype(jnp.float32),
             decay.transpose(2, 0, 1, 3)))
        o = os.transpose(1, 2, 0, 3)        # (B,H,T,Dv)
        new_state = {"S": S, "shift": x[:, -1]}
    elif use_pallas:
        from ..kernels.linear_scan import rwkv6_chunked
        tpad = (-t) % chunk
        pad4 = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, tpad), (0, 0)))
        o = rwkv6_chunked(pad4(r), pad4(k), pad4(v), pad4(w),
                          params["u"], chunk=chunk)[:, :, :t]
    else:
        tpad = (-t) % chunk
        pad4 = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, tpad), (0, 0)))
        o, _ = _chunked_jnp(pad4(r), pad4(k), pad4(v), pad4(w),
                            params["u"], chunk)
        o = o[:, :, :t]

    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    o = layers.rmsnorm(o.astype(x.dtype), params["ln_x"], cfg.norm_eps)
    out = jnp.dot((o.astype(jnp.float32) * g).astype(x.dtype), params["w_o"])
    return out, new_state


def rwkv_channel_mix(params, x):
    k = jnp.dot(x, params["cm_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(jnp.dot(x, params["cm_r"]).astype(jnp.float32))
    return (r * jnp.dot(k, params["cm_v"]).astype(jnp.float32)).astype(x.dtype)


def rwkv_init_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    return {
        "S": jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
        "shift": jnp.zeros((batch, d), layers.jdtype(cfg.dtype)),
    }
