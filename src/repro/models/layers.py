"""Shared pure-JAX building blocks: norms, FFN, RoPE, embeddings, init."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_DT = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f16": jnp.float16}


def jdtype(name: str):
    return _DT[name]


# --- init ---------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = (scale if scale is not None else 1.0) / max(1.0, fan_in) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)


# --- norms ----------------------------------------------------------------

def rmsnorm(x, gamma, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rmsnorm_init(d, dtype):
    return jnp.ones((d,), jdtype(dtype))


# --- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions):
    """positions: (...,) int -> (..., head_dim//2) angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x, positions, theta: float):
    """x: (B, H, T, D) with D even; positions: (T,) or (B, T)."""
    d = x.shape[-1]
    ang = rope_freqs(d, theta, positions)            # (T, D/2) or (B, T, D/2)
    if ang.ndim == 2:
        ang = ang[None, None]                        # (1, 1, T, D/2)
    else:
        ang = ang[:, None]                           # (B, 1, T, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- FFN ----------------------------------------------------------------------

def swiglu_init(key, d, ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, ff), jdtype(dtype)),
        "w_up": dense_init(k2, (d, ff), jdtype(dtype)),
        "w_down": dense_init(k3, (ff, d), jdtype(dtype)),
    }


def swiglu(params, x):
    g = jnp.dot(x, params["w_gate"])
    u = jnp.dot(x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.dot(h, params["w_down"])


# --- embedding / head -----------------------------------------------------------

def embedding_init(key, vocab, d, dtype):
    return {"table": embed_init(key, (vocab, d), jdtype(dtype))}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x, table=None):
    t = table if table is not None else params["table"]
    return jnp.dot(x, t.T.astype(x.dtype), preferred_element_type=jnp.float32)


# --- loss --------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """logits: (B, T, V) f32; labels: (B, T) int32.  Mean over valid tokens."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
