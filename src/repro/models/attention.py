"""Attention layers (GQA/MHA/MQA self-, cross-, and MLA latent attention).

Three interchangeable inner implementations, all semantically the TL
program (same online-softmax recurrence, same bottom-right causal mask):

* ``tl_pallas``  — the TL-generated Pallas kernel (interpret-mode on CPU,
                   Mosaic on TPU).  Used by smoke tests and TPU runtime.
* ``xla_flash``  — the same blocked online-softmax lowered through XLA as a
                   ``lax.scan`` over KV chunks.  This is the dry-run compile
                   path: it reproduces flash attention's O(M) memory profile
                   in HLO so the roofline terms are honest at 32k-512k
                   sequence lengths.
* ``naive``      — reference einsum (tests only).

GQA is computed grouped — q reshaped to (B, Hkv, G, M, D) — so KV is never
materialised per q-head (matters at Hq/Hkv = 16 on llama3-405b).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.translate import semantics
from . import layers
from .config import ModelConfig


# --------------------------------------------------------------------------
# inner attention
# --------------------------------------------------------------------------

def xla_flash(q, k, v, *, causal: bool, scale: float,
              window: Optional[int] = None, kv_valid=None,
              chunk: int = 1024, prechunked: bool = False,
              num_splits: int = 1, return_state: bool = False):
    """Chunked online-softmax attention.  q: (B,Hq,M,D), k/v: (B,Hkv,N,Dv).

    ``kv_valid``: number of valid KV entries — None (all), a scalar, or a
    per-batch-row (B,) vector (length-heterogeneous serving batches).

    ``prechunked``: k/v are already in the scan-operand layout
    ``(nc, B, Hkv, chunk, D)`` — the shape a paged-cache page gather
    produces naturally (one chunk per page), which skips materialising
    the dense ``(B, Hkv, N, D)`` view just to re-chunk it here.

    ``num_splits`` > 1 is the split-KV (Flash-Decoding) lowering for this
    backend: the KV chunks are partitioned into that many contiguous
    slices *folded into the batch axis*, so the scan shortens by the
    split factor while each step's GEMMs grow by it — the XLA analogue of
    the Pallas backend's parallel split grid — and the per-split online
    softmax states are LSE-merged (:func:`semantics.lse_merge`) before
    normalisation.  Requests are clamped to whole chunks (a divisor of
    the chunk count), so the merged result is numerically the single-scan
    answer.

    ``return_state``: return the *pre-divide* online-softmax state
    ``(acc, m, l)`` — f32, shaped ``(B,Hq,M,Dv)`` / ``(B,Hq,M,1)`` — instead
    of the normalised output.  Sequence-sharded callers LSE-merge these
    states across mesh ranks (:func:`semantics.lse_merge_axis`) before the
    epilogue divide."""
    b, hq, m, d = q.shape
    if prechunked:
        nc, _, hkv, chunk, dv = v.shape
        n = nc * chunk
        kc, vc = k, v
    else:
        hkv, n = k.shape[1], k.shape[2]
        dv = v.shape[-1]
        if int(num_splits) > 1:
            # give the split fold room: at most one chunk per split
            chunk = max(1, min(chunk, -(-n // int(num_splits))))
        chunk = min(chunk, n)
        nc = -(-n // chunk)
        npad = nc * chunk
        if npad != n:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, npad - n), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, npad - n), (0, 0)))
        kc = k.reshape(b, hkv, nc, chunk, k.shape[-1]).transpose(2, 0, 1, 3, 4)
        vc = v.reshape(b, hkv, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    g = hq // hkv
    # split-KV: largest feasible split count — whole chunks, divisor of nc
    ns = max(1, min(int(num_splits), nc))
    while nc % ns:
        ns -= 1
    ncs = nc // ns
    if kv_valid is None:
        kv_limit = n
    else:
        kv_limit = jnp.asarray(kv_valid)
        if kv_limit.ndim == 1:   # per-row lengths: broadcast over (B,K,G,M,C)
            kv_limit = kv_limit.reshape(b, 1, 1, 1, 1)
    q5 = q.reshape(b, hkv, g, m, d)
    if ns > 1:
        # fold the split axis into batch: scan step j now covers global
        # chunk s * ncs + j for every split s at once
        kc = kc.reshape(ns, ncs, b, hkv, chunk, kc.shape[-1]) \
            .transpose(1, 0, 2, 3, 4, 5) \
            .reshape(ncs, ns * b, hkv, chunk, kc.shape[-1])
        vc = vc.reshape(ns, ncs, b, hkv, chunk, dv) \
            .transpose(1, 0, 2, 3, 4, 5).reshape(ncs, ns * b, hkv, chunk, dv)
        q5 = jnp.broadcast_to(q5[None], (ns,) + q5.shape) \
            .reshape(ns * b, hkv, g, m, d)
        if kv_valid is not None and jnp.ndim(kv_limit) > 0:
            kv_limit = jnp.broadcast_to(kv_limit[None],
                                        (ns,) + kv_limit.shape) \
                .reshape((ns * b,) + kv_limit.shape[1:])
        # each folded row's chunk index offset within the full KV axis
        split_off = jnp.repeat(jnp.arange(ns) * (ncs * chunk),
                               b).reshape(ns * b, 1, 1, 1, 1)
    bsz = ns * b if ns > 1 else b
    q_off = kv_limit - m  # bottom-right causal alignment (last q = last key)

    q_pos = jnp.arange(m).reshape(1, 1, 1, m, 1) + q_off

    def step(carry, xs):
        m_run, l_run, acc = carry
        ci, k_i, v_i = xs
        s = jnp.einsum("bkgmd,bknd->bkgmn", q5.astype(jnp.float32),
                       k_i.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        k_pos = (ci * chunk + jnp.arange(chunk)).reshape(1, 1, 1, 1, chunk)
        if ns > 1:
            k_pos = k_pos + split_off
        keep = k_pos < kv_limit
        if causal:
            keep = keep & (k_pos <= q_pos)
        if window is not None:
            keep = keep & (k_pos > q_pos - window)
        s = jnp.where(keep, s, semantics.NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_run, m_cur)
        p = jnp.exp(s - m_new)
        # fully-masked rows stay at 0 (see semantics.online_softmax)
        p = jnp.where(m_new <= semantics.NEG_INF / 2, 0.0, p)
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bkgmn,bknd->bkgmd", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((bsz, hkv, g, m, 1), semantics.NEG_INF, jnp.float32)
    l0 = jnp.zeros((bsz, hkv, g, m, 1), jnp.float32)
    a0 = jnp.zeros((bsz, hkv, g, m, dv), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(ncs if ns > 1 else nc), kc, vc))
    if ns > 1:
        # LSE-merge the per-split partial states (Flash-Decoding combine)
        acc, m_f, l_f = semantics.lse_merge(
            acc.reshape((ns, b) + acc.shape[1:]),
            m_f.reshape((ns, b) + m_f.shape[1:]),
            l_f.reshape((ns, b) + l_f.shape[1:]))
    if return_state:
        return (acc.reshape(b, hq, m, dv),
                m_f.reshape(b, hq, m, 1), l_f.reshape(b, hq, m, 1))
    out = acc / jnp.where(l_f == 0.0, 1.0, l_f)
    return out.reshape(b, hq, m, dv).astype(q.dtype)


def naive_attention(q, k, v, *, causal, scale, window=None, kv_valid=None):
    from ..kernels import ref
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale,
                         kv_valid=kv_valid).astype(q.dtype)


def _resolve_splits(num_splits, *, rows: int, kv_len: int,
                    page_size=None, mode: str = "decode",
                    target: str = "v5e") -> int:
    """Decode/verify split-KV count for the XLA scan backend — the same
    resolution point as the TL pipeline (one decision, two lowerings)."""
    from ..core.reason import resolve_num_splits
    return resolve_num_splits(num_splits, rows=rows, kv_len=kv_len,
                              mode=mode, page_size=page_size, target=target)


# --------------------------------------------------------------------------
# paged KV cache (decode)
# --------------------------------------------------------------------------

def _deq(gathered, tables, scale):
    """Dequantize a page gather: ``gathered`` is ``pool[tables]`` with
    leading (B, Tp) axes; ``scale`` the (P,) f32 per-page absmax table.
    One scalar per page — the same contract the Pallas kernel applies per
    KV tile inside its inner loop."""
    s = jnp.asarray(scale, jnp.float32).reshape(-1)[tables]       # (B, Tp)
    return (gathered.astype(jnp.float32)
            * s.reshape(s.shape + (1,) * (gathered.ndim - 2)))


def gather_pages(pool, tables, scale=None):
    """Materialise the dense per-row cache view of a page pool.

    ``pool``: (P, Hkv, ps, D) KV pool or (P, ps, D) MLA latent pool;
    ``tables``: (B, Tp) int32 physical page per logical page.  Returns
    (B, Hkv, Tp*ps, D) / (B, Tp*ps, D).  This is the *definition* of the
    paged layout — the Pallas kernel's block-table gather must agree with
    it, and the XLA/naive decode fallbacks attend through it directly.
    ``scale``: (P,) f32 per-page absmax scales for an int8 pool — the
    gather dequantizes to f32 on the way out.
    """
    g = pool[tables]                                  # (B, Tp, ...)
    if scale is not None:
        g = _deq(g, tables, scale)
    if pool.ndim == 4:
        b, tp, hkv, ps, d = g.shape
        return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, tp * ps, d)
    b, tp, ps, d = g.shape
    return g.reshape(b, tp * ps, d)


def gather_prechunked(pool, tables, scale=None):
    """Page gather in :func:`xla_flash`'s ``prechunked`` operand layout —
    one scan chunk per page, (Tp, B, ..., ps, D) — dequantizing int8
    pools (``scale``: (P,) f32) on the way."""
    g = pool[tables]
    if scale is not None:
        g = _deq(g, tables, scale)
    return jnp.moveaxis(g, 1, 0)


def paged_scatter(pool, tables, pos, new):
    """Write one new token per batch row into its pool page.

    ``pool``: (P, Hkv, ps, D) or (P, ps, D); ``tables``: (B, Tmax) int32;
    ``pos``: (B,) logical write positions (the rows' cache lengths);
    ``new``: (B, Hkv, D) / (B, D) token values.  The page
    ``tables[b, pos // ps]`` must already be allocated (the engine's
    allocate-on-write step guarantees it; idle rows point at a reserved
    dump page)."""
    ps = pool.shape[-2]
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    pages = jnp.take_along_axis(
        jnp.asarray(tables, jnp.int32), (pos // ps)[:, None], axis=1)[:, 0]
    if pool.ndim == 4:
        return pool.at[pages, :, pos % ps].set(new)
    return pool.at[pages, pos % ps].set(new)


def paged_scatter_chunk(pool, tables, start, new, valid=None):
    """Write a whole chunk of tokens per batch row into its pool pages.

    ``pool``: (P, Hkv, ps, D) or (P, ps, D); ``tables``: (B, Tmax) int32;
    ``start``: (B,) logical positions of the chunk's first token; ``new``:
    (B, Hkv, C, D) / (B, C, D) chunk values.  Token ``j`` of row ``b``
    lands in page ``tables[b, (start[b]+j) // ps]`` at slot
    ``(start[b]+j) % ps`` — every touched table entry must be a valid pool
    index (the engine pads tables with its reserved dump page, so a padded
    tail chunk spills harmlessly into the dump page).

    ``valid``: optional (B,) runtime count of real tokens at the head of
    each row's chunk — positions ``j >= valid[b]`` keep the pool's
    existing content instead of writing.  A padded tail chunk may not
    assume it owns its last page's tail: once full pages are published to
    the prefix index mid-prefill, another request can be holding (or
    adopting) that page before the pad positions would land."""
    ps = pool.shape[-2]
    c = new.shape[-2]
    start = jnp.asarray(start, jnp.int32).reshape(-1)
    pos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # (B, C)
    pages = jnp.take_along_axis(jnp.asarray(tables, jnp.int32),
                                pos // ps, axis=1)                  # (B, C)
    slots = pos % ps
    keep = None
    if valid is not None:
        keep = (jnp.arange(c, dtype=jnp.int32)[None, :]
                < jnp.asarray(valid, jnp.int32).reshape(-1)[:, None])
    if pool.ndim == 4:
        # advanced indices (B,C) around the Hkv slice -> (B, C, Hkv, D)
        upd = jnp.moveaxis(new, 1, 2)
        if keep is not None:
            upd = jnp.where(keep[..., None, None], upd,
                            pool[pages, :, slots])
        return pool.at[pages, :, slots].set(upd)
    upd = new
    if keep is not None:
        upd = jnp.where(keep[..., None], upd, pool[pages, slots])
    return pool.at[pages, slots].set(upd)


# int8 page quantization: symmetric absmax, one f32 scale per *page*.
_QMAX = 127.0     # int8 range used symmetrically (-127..127; -128 unused)
_QTINY = 1e-30    # guards 0-divide on never-written (scale 0.0) pages


def _quant_rescale(pool, scale, pages, amax):
    """Shared write-side scale bookkeeping.  ``pages``/``amax`` are the
    flat pages being written and the absmax of each write.  Grows the
    per-page running-max scales, renormalises the pool's existing int8
    content wherever a scale grew (ratio multiply + round — the ratio is
    exactly 1.0 for untouched pages, so only written pages can move), and
    returns ``(pool, grown_scales)``."""
    old = jnp.asarray(scale, jnp.float32).reshape(-1)
    grown = old.at[pages.reshape(-1)].max(amax.reshape(-1) / _QMAX)
    ratio = jnp.where(grown > old, old / jnp.maximum(grown, _QTINY), 1.0)
    rsh = ratio.reshape((-1,) + (1,) * (pool.ndim - 1))
    pool = jnp.round(pool.astype(jnp.float32) * rsh).astype(jnp.int8)
    return pool, grown


def _quantize(new32, s_tok):
    """Quantize f32 values against their pages' (broadcast) scales."""
    s = jnp.maximum(s_tok, _QTINY)
    s = s.reshape(s.shape + (1,) * (new32.ndim - s.ndim))
    return jnp.clip(jnp.round(new32 / s), -_QMAX, _QMAX).astype(jnp.int8)


def paged_scatter_quant(pool, tables, pos, new, *, scale, amax_axis=None):
    """Quantizing :func:`paged_scatter` for int8 page pools.

    ``pool``: int8 (P, Hkv, ps, D) / (P, ps, D); ``scale``: (P,) f32
    per-page absmax scales (dequant value = int8 * scale).  Scales are a
    *running max*: a token whose absmax exceeds ``127 * scale`` of its
    page grows that page's scale, renormalising the page's existing int8
    content to the new scale before the token is quantized in (bounded
    requantization error ≤ half a quantum of the grown scale).  Returns
    ``(pool, scale)`` — the caller threads both through the cache.

    ``amax_axis``: named mesh axis to ``pmax`` the per-token absmax over
    before growing scales.  Head-sharded pools (tensor-parallel serving)
    hold disjoint head slices per shard, but the per-page scale table is
    *replicated* — maxing the absmax across the axis keeps every shard's
    scales byte-identical to the single-device pool's."""
    ps = pool.shape[-2]
    pos = jnp.asarray(pos, jnp.int32).reshape(-1)
    pages = jnp.take_along_axis(
        jnp.asarray(tables, jnp.int32), (pos // ps)[:, None], axis=1)[:, 0]
    new32 = jnp.asarray(new, jnp.float32)
    amax = jnp.abs(new32).reshape(new32.shape[0], -1).max(axis=1)   # (B,)
    if amax_axis is not None:
        amax = jax.lax.pmax(amax, amax_axis)
    pool, grown = _quant_rescale(pool, scale, pages, amax)
    q = _quantize(new32, grown[pages])
    if pool.ndim == 4:
        return pool.at[pages, :, pos % ps].set(q), grown
    return pool.at[pages, pos % ps].set(q), grown


def paged_scatter_chunk_quant(pool, tables, start, new, *, scale, valid=None,
                              amax_axis=None):
    """Quantizing :func:`paged_scatter_chunk`.  ``scale``/``valid``/
    ``amax_axis`` follow :func:`paged_scatter_quant` /
    :func:`paged_scatter_chunk`; positions past ``valid`` neither write the
    pool nor bump any page's scale (a padded tail chunk may not touch pages
    another request already owns).  Returns ``(pool, scale)``."""
    ps = pool.shape[-2]
    c = new.shape[-2]
    start = jnp.asarray(start, jnp.int32).reshape(-1)
    pos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # (B, C)
    pages = jnp.take_along_axis(jnp.asarray(tables, jnp.int32),
                                pos // ps, axis=1)                  # (B, C)
    slots = pos % ps
    keep = None
    if valid is not None:
        keep = (jnp.arange(c, dtype=jnp.int32)[None, :]
                < jnp.asarray(valid, jnp.int32).reshape(-1)[:, None])
    new32 = jnp.asarray(new, jnp.float32)
    upd = jnp.moveaxis(new32, 1, 2) if pool.ndim == 4 else new32
    amax = jnp.abs(upd).reshape(upd.shape[0], c, -1).max(axis=-1)   # (B, C)
    if keep is not None:
        amax = jnp.where(keep, amax, 0.0)
    if amax_axis is not None:
        amax = jax.lax.pmax(amax, amax_axis)
    pool, grown = _quant_rescale(pool, scale, pages, amax)
    q = _quantize(upd, grown[pages])
    if pool.ndim == 4:
        if keep is not None:
            q = jnp.where(keep[..., None, None], q, pool[pages, :, slots])
        return pool.at[pages, :, slots].set(q), grown
    if keep is not None:
        q = jnp.where(keep[..., None], q, pool[pages, slots])
    return pool.at[pages, slots].set(q), grown


def run_paged_prefill(q, k_pool, v_pool, tables, *, cfg: ModelConfig,
                      hist_len, scale: float, kv_scales=None):
    """Chunked prefill attention through a block table: the chunk's q rows
    attend causally to the pages already written (history + the chunk
    itself — scatter first, then attend).  ``hist_len`` is the per-row
    cache length *before* this chunk.  Pallas shifts the causal diagonal
    by the runtime history inside the kernel; the XLA/naive paths feed the
    page gather into the flash scan, whose bottom-right alignment
    (``q_off = kv_valid - M``) lands on the same diagonal.
    ``kv_scales``: ``(k_scale, v_scale)`` per-page (P,) f32 absmax scales
    iff the pools are int8 — Pallas dequantizes inside its KV loop, the
    fallbacks dequantize the page gather."""
    c = q.shape[2]
    ks, vs = kv_scales if kv_scales is not None else (None, None)
    if cfg.attn_impl == "tl_pallas":
        from ..kernels import ops
        return ops.paged_flash_prefill(
            q, k_pool, v_pool, tables, hist_len=hist_len,
            kv_scales=kv_scales).astype(q.dtype)
    kv_valid = jnp.asarray(hist_len).reshape(-1) + c
    if cfg.attn_impl == "naive":
        return naive_attention(q, gather_pages(k_pool, tables, ks),
                               gather_pages(v_pool, tables, vs),
                               causal=True, scale=scale, kv_valid=kv_valid)
    kc = gather_prechunked(k_pool, tables, ks)  # (tp, B, Hkv, ps, D)
    vc = gather_prechunked(v_pool, tables, vs)
    return xla_flash(q, kc, vc, causal=True, scale=scale, kv_valid=kv_valid,
                     prechunked=True)


def run_paged_verify(q, k_pool, v_pool, tables, *, cfg: ModelConfig,
                     hist_len, scale: float, num_splits=None,
                     kv_scales=None):
    """Speculative-decode verification through a block table: the K+1
    candidate rows (committed token + drafts, K/V already scattered)
    attend causally to history + themselves, like
    :func:`run_paged_prefill`, but the TL mode is ``verify`` — decode's
    split-KV partitioning rides on top of the chunk tiling for long
    caches.  ``num_splits`` follows :func:`run_paged_decode` (None =
    reasoned per backend via the autotuner's split scoring);
    ``kv_scales`` follows :func:`run_paged_prefill`."""
    c = q.shape[2]
    ks, vs = kv_scales if kv_scales is not None else (None, None)
    if cfg.attn_impl == "tl_pallas":
        from ..kernels import ops
        return ops.paged_flash_verify(
            q, k_pool, v_pool, tables, hist_len=hist_len,
            num_splits=num_splits, kv_scales=kv_scales).astype(q.dtype)
    kv_valid = jnp.asarray(hist_len).reshape(-1) + c
    if cfg.attn_impl == "naive":
        return naive_attention(q, gather_pages(k_pool, tables, ks),
                               gather_pages(v_pool, tables, vs),
                               causal=True, scale=scale, kv_valid=kv_valid)
    kc = gather_prechunked(k_pool, tables, ks)  # (tp, B, Hkv, ps, D)
    vc = gather_prechunked(v_pool, tables, vs)
    ps = k_pool.shape[-2]
    return xla_flash(q, kc, vc, causal=True, scale=scale, kv_valid=kv_valid,
                     prechunked=True,
                     num_splits=_resolve_splits(
                         num_splits, rows=q.shape[0] * q.shape[1],
                         kv_len=tables.shape[-1] * ps, page_size=ps,
                         mode="verify"))


def run_paged_decode(q, k_pool, v_pool, tables, *, cfg: ModelConfig,
                     cache_len, scale: float, num_splits=None,
                     kv_scales=None):
    """Decode attention through a block table (see :func:`gather_pages`).

    The Pallas kernel gathers pages inside its BlockSpec DMAs; the XLA
    path feeds the page gather straight into the flash scan as one chunk
    per page (``prechunked``), so neither materialises the dense
    ``(B, Hkv, N, D)`` cache view.  ``num_splits``: split-KV decode —
    None lets the reasoning heuristic decide per backend, 1 forces the
    sequential KV pass, >1 forces that many (clamped) splits.
    ``kv_scales`` follows :func:`run_paged_prefill`."""
    ks, vs = kv_scales if kv_scales is not None else (None, None)
    if cfg.attn_impl == "tl_pallas":
        from ..kernels import ops
        return ops.paged_flash_decode(
            q, k_pool, v_pool, tables, cache_len=cache_len,
            num_splits=num_splits, kv_scales=kv_scales).astype(q.dtype)
    if cfg.attn_impl == "naive":
        return naive_attention(q, gather_pages(k_pool, tables, ks),
                               gather_pages(v_pool, tables, vs),
                               causal=False, scale=scale, kv_valid=cache_len)
    kc = gather_prechunked(k_pool, tables, ks)  # (tp, B, Hkv, ps, D)
    vc = gather_prechunked(v_pool, tables, vs)
    ps = k_pool.shape[-2]
    return xla_flash(q, kc, vc, causal=False, scale=scale, kv_valid=cache_len,
                     prechunked=True,
                     num_splits=_resolve_splits(
                         num_splits, rows=q.shape[0] * k_pool.shape[1],
                         kv_len=tables.shape[-1] * ps, page_size=ps))


def run_attention(q, k, v, *, cfg: ModelConfig, causal: bool,
                  scale: float, window=None, kv_valid=None,
                  num_splits=None):
    impl = cfg.attn_impl
    decode = kv_valid is not None and q.shape[2] == 1
    if impl == "tl_pallas":
        from ..kernels import ops
        if decode:
            # decode: runtime-length kernel — kv_valid may be an int, a
            # traced scalar, or a per-request (B,) vector; the compiled
            # kernel is keyed on the cache *capacity* (the caller's length
            # bucket) and the split count, never on the step count
            return ops.flash_decode(q, k, v, cache_len=kv_valid,
                                    num_splits=num_splits).astype(q.dtype)
        if kv_valid is not None:
            # prefill into a cache buffer: only the first kv_valid entries
            # are real — slice them (kv_valid is static in the serve path;
            # a traced/per-row length falls back to the masked XLA path)
            try:
                n_valid = int(kv_valid)
            except (TypeError, jax.errors.TracerIntegerConversionError):
                return xla_flash(q, k, v, causal=causal, scale=scale,
                                 window=window, kv_valid=kv_valid,
                                 chunk=cfg.attn_chunk)
            k, v = k[:, :, :n_valid], v[:, :, :n_valid]
        return ops.flash_attention(q, k, v, causal=causal,
                                   window=window).astype(q.dtype)
    if impl == "xla_flash":
        splits = 1
        if decode:
            splits = _resolve_splits(num_splits, rows=q.shape[0] * k.shape[1],
                                     kv_len=k.shape[2])
        return xla_flash(q, k, v, causal=causal, scale=scale, window=window,
                         kv_valid=kv_valid, chunk=cfg.attn_chunk,
                         num_splits=splits)
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, scale=scale,
                               window=window, kv_valid=kv_valid)
    raise ValueError(f"unknown attn_impl {impl!r}")


# --------------------------------------------------------------------------
# GQA/MHA/MQA self-attention layer (and cross-attention)
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, cross: bool = False):
    d, hq, hkv, hd = cfg.d_model, cfg.num_q_heads, cfg.num_kv_heads, cfg.head_dim
    hq = max(hq, cfg.pad_q_heads_to)
    dt = layers.jdtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    kv_in = cfg.vision_d if cross and cfg.vision_d else d
    return {
        "wq": layers.dense_init(ks[0], (d, hq, hd), dt),
        "wk": layers.dense_init(ks[1], (kv_in, hkv, hd), dt),
        "wv": layers.dense_init(ks[2], (kv_in, hkv, hd), dt),
        "wo": layers.dense_init(ks[3], (hq, hd, d), dt,
                                scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }


def _constrain(v, spec):
    if spec is None:
        return v
    return jax.lax.with_sharding_constraint(v, spec)


def _cache_append(buf, new, start, axis: int):
    """Write ``new`` into ``buf`` at ``start`` along ``axis`` (post-batch).

    ``start`` is a scalar (length-homogeneous batch) or a per-batch-row
    (B,) vector — each request in a heterogeneous decode batch appends at
    its own cache length."""
    if jnp.ndim(start) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, start, axis)
    upd = jax.vmap(
        lambda b, n, s: jax.lax.dynamic_update_slice_in_dim(b, n, s, axis - 1))
    return upd(buf, new, start)


def attn_apply(params, x, *, cfg: ModelConfig, positions=None, cache=None,
               cross_kv=None, causal=True, head_sharding=None,
               kv_bucket=None, block_tables=None, page_size=None,
               num_splits=None, chunk_valid=None, verify=False, tp=None):
    """x: (B, T, d).  ``cache``: optional dict(k, v, len) for decode;
    ``cache['len']`` may be a scalar or a per-request (B,) vector.
    ``kv_bucket``: static length bucket — attention reads only the first
    ``kv_bucket`` cache entries (the update still writes the full buffer),
    so the serving engine compiles one decode step per bucket instead of
    one per cache length.
    ``num_splits``: split-KV decode partition count (None = the reasoning
    heuristic per backend; 1 = sequential KV pass; >1 forced, clamped).
    ``block_tables``/``page_size``: paged cache — ``cache['k']/['v']`` are
    then (P, Hkv, page_size, D) page *pools* shared across the batch, and
    ``block_tables`` (B, Tmax) maps logical to physical pages; the new
    token(s) are scattered into the rows' pages and attention gathers
    through the first ``kv_bucket // page_size`` table columns.  T == 1 is
    paged decode; T > 1 is one chunk of chunked prefill (causal against
    history + the chunk, the cache growing page-by-page instead of through
    a dense prefill buffer).  ``chunk_valid``: optional (B,) runtime count
    of real tokens in a padded prefill chunk — the scatter masks the pad
    tail so it never lands in the pages (causality already keeps real
    rows from attending to those positions).
    ``verify``: the T > 1 paged chunk is a speculative-decode draft window
    — same scatter + causal-against-history semantics, but attention runs
    the ``verify`` TL mode, which may split the KV axis (``num_splits``
    applies) for long caches.
    ``cross_kv``: (B, P, vision_d) patch embeddings for cross-attention.
    ``head_sharding``: PartitionSpec for (B, H, T, D) tensors — pins the
    q/o head dim to the 'model' axis so GSPMD never resolves the attention
    einsums by partial-summing a mis-sharded KV operand (a measured 2.7 TB
    of per-step all-reduce on deepseek-v2-lite, EXPERIMENTS.md §Perf).
    ``tp``: tensor-parallel serving context (``parallel.sharding.ServeTP``)
    when running *inside* ``shard_map`` — the params/pools this shard holds
    are already head slices under the 'kv'/'q' plans, so the math here is
    unchanged except that int8 scale growth maxes absmax across the axis
    (replicated scale tables stay byte-identical per shard); the caller
    (transformer) psums the wo output across the axis."""
    b, t, d = x.shape
    hd = cfg.head_dim
    q = _constrain(jnp.einsum("btd,dhk->bhtk", x, params["wq"]),
                   head_sharding)
    src = cross_kv if cross_kv is not None else x
    k = jnp.einsum("bpd,dhk->bhpk", src, params["wk"])
    v = jnp.einsum("bpd,dhk->bhpk", src, params["wv"])

    if cross_kv is None:
        if positions is None:
            positions = jnp.arange(t)
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)

    kv_valid = None
    paged = cache is not None and block_tables is not None
    if paged:
        # paged cache: scatter the new token(s) into the rows' pool pages,
        # then attend through the block table.  T == 1 is decode; T > 1 is
        # one chunk of chunked prefill (the chunk's K/V land in the pages
        # first, then the chunk attends causally to history + itself).
        if page_size is None:
            raise ValueError("block_tables given without page_size — the "
                             "paged cache layout needs both")
        hist = cache["len"]
        tpc = ((kv_bucket if kv_bucket is not None
                else block_tables.shape[1] * page_size) // page_size)
        # int8-quantized pools carry per-page scale leaves ("ks"/"vs");
        # the quantizing scatter threads them, attention dequantizes
        quant = "ks" in cache
        # head-sharded pools (kv plan): scale growth maxes across the axis
        amax_axis = (tp.axis if tp is not None and tp.plan == "kv"
                     and tp.size > 1 else None)
        scales = None
        if t == 1:
            if quant:
                kp, ksc = paged_scatter_quant(cache["k"], block_tables,
                                              hist, k[:, :, 0],
                                              scale=cache["ks"],
                                              amax_axis=amax_axis)
                vp, vsc = paged_scatter_quant(cache["v"], block_tables,
                                              hist, v[:, :, 0],
                                              scale=cache["vs"],
                                              amax_axis=amax_axis)
                scales = (ksc, vsc)
            else:
                kp = paged_scatter(cache["k"], block_tables, hist,
                                   k[:, :, 0])
                vp = paged_scatter(cache["v"], block_tables, hist,
                                   v[:, :, 0])
            cache = {"k": kp, "v": vp, "len": hist + t}
            if quant:
                cache["ks"], cache["vs"] = scales
            kv_valid = cache["len"]
            o = run_paged_decode(q, kp, vp, block_tables[:, :tpc], cfg=cfg,
                                 cache_len=kv_valid, scale=hd ** -0.5,
                                 num_splits=num_splits, kv_scales=scales)
        else:
            if quant:
                kp, ksc = paged_scatter_chunk_quant(
                    cache["k"], block_tables, hist, k,
                    scale=cache["ks"], valid=chunk_valid,
                    amax_axis=amax_axis)
                vp, vsc = paged_scatter_chunk_quant(
                    cache["v"], block_tables, hist, v,
                    scale=cache["vs"], valid=chunk_valid,
                    amax_axis=amax_axis)
                scales = (ksc, vsc)
            else:
                kp = paged_scatter_chunk(cache["k"], block_tables, hist, k,
                                         valid=chunk_valid)
                vp = paged_scatter_chunk(cache["v"], block_tables, hist, v,
                                         valid=chunk_valid)
            cache = {"k": kp, "v": vp, "len": hist + t}
            if quant:
                cache["ks"], cache["vs"] = scales
            if verify:
                o = run_paged_verify(q, kp, vp, block_tables[:, :tpc],
                                     cfg=cfg, hist_len=hist,
                                     scale=hd ** -0.5,
                                     num_splits=num_splits,
                                     kv_scales=scales)
            else:
                o = run_paged_prefill(q, kp, vp, block_tables[:, :tpc],
                                      cfg=cfg, hist_len=hist,
                                      scale=hd ** -0.5, kv_scales=scales)
    elif cache is not None:
        # decode: append new kv at cache['len'] (per-request positions for
        # heterogeneous batches), attend to the prefix
        k = _cache_append(cache["k"], k, cache["len"], 2)
        v = _cache_append(cache["v"], v, cache["len"], 2)
        cache = {"k": k, "v": v, "len": cache["len"] + t}
        kv_valid = cache["len"]
        if kv_bucket is not None:
            # static bucket slice: compute reads bucket-many entries, the
            # runtime kv_valid mask handles the tail inside the bucket
            k, v = k[:, :, :kv_bucket], v[:, :, :kv_bucket]

    if not paged:
        o = run_attention(q, k, v, cfg=cfg,
                          causal=causal and cross_kv is None,
                          scale=hd ** -0.5, kv_valid=kv_valid,
                          num_splits=num_splits)
    o = _constrain(o, head_sharding)
    o = o.astype(x.dtype)
    if cfg.pad_q_heads_to > cfg.num_q_heads:
        # zero the padded heads so their (garbage) attention output cannot
        # reach wo — keeps values AND gradients exactly those of the
        # unpadded model.  Pad slots are interleaved per KV group (real
        # heads fill the first g slots of each group) so the GQA head->KV
        # mapping is preserved.
        g_pad = cfg.pad_q_heads_to // cfg.num_kv_heads
        g_real = cfg.num_q_heads // cfg.num_kv_heads
        mask = (jnp.arange(o.shape[1]) % g_pad) < g_real
        o = o * mask[None, :, None, None].astype(o.dtype)
    out = jnp.einsum("bhtk,hkd->btd", o, params["wo"])
    return (out, cache) if cache is not None else (out, None)


def cross_attn_apply(params, x, *, cfg: ModelConfig, vision=None, cache=None):
    """Cross-attention over patch embeddings, with KV caching.

    Prefill (``vision`` given): compute K/V from the patch embeddings and
    return them as the cache.  Decode (``vision`` None, ``cache`` given):
    reuse the cached projections — the image is encoded exactly once.
    """
    b, t, d = x.shape
    q = jnp.einsum("btd,dhk->bhtk", x, params["wq"])
    if vision is not None:
        k = jnp.einsum("bpd,dhk->bhpk", vision.astype(x.dtype), params["wk"])
        v = jnp.einsum("bpd,dhk->bhpk", vision.astype(x.dtype), params["wv"])
        new_cache = {"k": k, "v": v} if cache is not None else None
    elif cache is not None:
        k, v = cache["k"], cache["v"]
        new_cache = {"k": k, "v": v}
    else:
        raise ValueError("cross-attention needs vision embeds or a cache")
    o = run_attention(q, k, v, cfg=cfg, causal=False,
                      scale=cfg.head_dim ** -0.5)
    out = jnp.einsum("bhtk,hkd->btd", o.astype(x.dtype), params["wo"])
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek V2/V3) — absorbed latent attention
# --------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_q_heads
    r, rr = cfg.kv_lora_rank, cfg.rope_head_dim
    nope, vd = cfg.nope_head_dim, cfg.v_head_dim
    dt = layers.jdtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    p = {
        "w_dkv": layers.dense_init(ks[0], (d, r + rr), dt),
        "kv_norm": layers.rmsnorm_init(r, cfg.dtype),
        "w_uk": layers.dense_init(ks[1], (r, h, nope), dt),
        "w_uv": layers.dense_init(ks[2], (r, h, vd), dt),
        "w_o": layers.dense_init(ks[3], (h, vd, d), dt,
                                 scale=1.0 / (2 * cfg.num_layers) ** 0.5),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = layers.dense_init(ks[4], (d, cfg.q_lora_rank), dt)
        p["q_norm"] = layers.rmsnorm_init(cfg.q_lora_rank, cfg.dtype)
        p["w_uq"] = layers.dense_init(ks[5], (cfg.q_lora_rank, h, nope + rr), dt)
    else:
        p["w_q"] = layers.dense_init(ks[6], (d, h, nope + rr), dt)
    return p


def mla_apply(params, x, *, cfg: ModelConfig, positions=None, cache=None,
              causal=True, head_sharding=None, latent_sharding=None,
              kv_bucket=None, block_tables=None, page_size=None,
              num_splits=None, chunk_valid=None, verify=False, tp=None):
    """Absorbed MLA.  The latent cache (R + Rr per token, head-independent)
    is both K and V — read once for both GEMMs (paper Table 2 workload).
    ``cache['len']``/``kv_bucket``/``block_tables``/``page_size``/
    ``num_splits``/``chunk_valid``/``verify`` follow :func:`attn_apply`;
    the paged pool is (P, page_size, R+Rr).  MLA decode launches only B
    programs (one latent KV head), so the split heuristic engages earliest
    here.

    ``tp``: tensor-parallel serving context inside ``shard_map``.  MLA has
    one latent KV head, so head sharding cannot help — the ``'seq'`` plan
    keeps the pool, tables and params replicated and splits the *sequence*:
    each rank attends over its contiguous slice of table columns with a
    rank-local history length, and the per-rank online-softmax states
    LSE-merge across the axis (:func:`semantics.lse_merge_axis`) before the
    epilogue divide — exactly split-KV decode with the mesh axis as the
    split grid, so the merged result is bit-identical to one device."""
    b, t, d = x.shape
    h, r, rr = cfg.num_q_heads, cfg.kv_lora_rank, cfg.rope_head_dim
    nope = cfg.nope_head_dim
    if positions is None:
        positions = jnp.arange(t)

    # --- latent KV: c_kv (normed) ++ shared roped k_rope --------------------
    ckv = jnp.einsum("btd,dr->btr", x, params["w_dkv"])
    c, k_rope = ckv[..., :r], ckv[..., r:]
    c = layers.rmsnorm(c, params["kv_norm"], cfg.norm_eps)
    k_rope = layers.apply_rope(k_rope[:, None], positions,
                               cfg.rope_theta)[:, 0]
    latent = jnp.concatenate([c, k_rope.astype(c.dtype)], axis=-1)  # (B,T,R+Rr)

    # --- queries, absorbed into latent space --------------------------------
    if cfg.q_lora_rank:
        qc = layers.rmsnorm(jnp.einsum("btd,dr->btr", x, params["w_dq"]),
                            params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("btr,rhk->bhtk", qc, params["w_uq"])
    else:
        q = jnp.einsum("btd,dhk->bhtk", x, params["w_q"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    q_lat = jnp.einsum("bhtn,rhn->bhtr", q_nope, params["w_uk"])
    q_full = _constrain(
        jnp.concatenate([q_lat, q_rope.astype(q_lat.dtype)], axis=-1),
        head_sharding)
    # the shared latent cache is small (N x (R+Rr)); keep it replicated
    # over 'model' so the two latent GEMMs contract locally per head shard
    latent = _constrain(latent, latent_sharding)

    kv_valid = None
    paged = cache is not None and block_tables is not None
    if paged:
        if page_size is None:
            raise ValueError("block_tables given without page_size — the "
                             "paged cache layout needs both")
        hist = cache["len"]
        quant = "cs" in cache   # int8 latent pool + per-page scale leaf
        c_scale = None
        if t == 1:
            if quant:
                pool, c_scale = paged_scatter_quant(
                    cache["c"], block_tables, hist, latent[:, 0],
                    scale=cache["cs"])
            else:
                pool = paged_scatter(cache["c"], block_tables, hist,
                                     latent[:, 0])
        else:   # one chunk of chunked prefill
            if quant:
                pool, c_scale = paged_scatter_chunk_quant(
                    cache["c"], block_tables, hist, latent,
                    scale=cache["cs"], valid=chunk_valid)
            else:
                pool = paged_scatter_chunk(cache["c"], block_tables, hist,
                                           latent, valid=chunk_valid)
        cache = {"c": pool, "len": hist + t}
        if quant:
            cache["cs"] = c_scale
        kv_valid = cache["len"]
    elif cache is not None:
        latent = _cache_append(cache["c"], latent, cache["len"], 1)
        cache = {"c": latent, "len": cache["len"] + t}
        kv_valid = cache["len"]
        if kv_bucket is not None:
            latent = latent[:, :kv_bucket]

    scale = (nope + rr) ** -0.5
    if paged:
        tpc = ((kv_bucket if kv_bucket is not None
                else block_tables.shape[1] * page_size) // page_size)
        tbl = block_tables[:, :tpc]
        # 'seq' plan: this rank covers a contiguous slice of the bucket's
        # table columns; lengths shift by the rank's token offset (they may
        # go negative past the valid region — those ranks mask everything
        # and their NEG_INF states merge with zero weight)
        seq = (tp is not None and tp.plan == "seq" and tp.size > 1)
        seq_off = None
        if seq:
            if tpc % tp.size:
                raise ValueError(
                    f"seq-plan bucket ({tpc} pages) must divide over the "
                    f"model axis ({tp.size}) — the engine floors the "
                    "bucket at page_size * axis size")
            tpr = tpc // tp.size
            rank = jax.lax.axis_index(tp.axis)
            tbl = jax.lax.dynamic_slice_in_dim(tbl, rank * tpr, tpr, axis=1)
            seq_off = rank * (tpr * page_size)
        if cfg.attn_impl == "tl_pallas":
            from ..kernels import ops
            axis = tp.axis if seq else None
            lens_d = kv_valid if not seq else jnp.asarray(kv_valid) - seq_off
            lens_h = hist if not seq else jnp.asarray(hist) - seq_off
            if t == 1:
                o_lat = ops.paged_mla_decode(q_full, pool, tbl,
                                             cache_len=lens_d,
                                             c_scale=c_scale,
                                             num_splits=num_splits,
                                             kv_lora_rank=r,
                                             rope_head_dim=rr,
                                             shard_axis=axis)
            elif verify:
                o_lat = ops.paged_mla_verify(q_full, pool, tbl,
                                             hist_len=lens_h,
                                             c_scale=c_scale,
                                             num_splits=num_splits,
                                             kv_lora_rank=r,
                                             rope_head_dim=rr,
                                             shard_axis=axis)
            else:
                o_lat = ops.paged_mla_prefill(q_full, pool, tbl,
                                              hist_len=lens_h,
                                              c_scale=c_scale,
                                              kv_lora_rank=r,
                                              rope_head_dim=rr,
                                              shard_axis=axis)
        else:
            # page gather straight into the flash scan: one chunk per page
            # (dequantizing an int8 latent pool on the way)
            lat = gather_prechunked(pool, tbl, c_scale)[:, :, None]
            ps = pool.shape[-2]
            if seq:
                # per-rank flash scan over the local slice, then the
                # cross-rank LSE merge; local kv_valid keeps the causal
                # diagonal aligned (both q and k positions shift by the
                # same rank offset)
                acc, m_f, l_f = xla_flash(
                    q_full, lat, lat[..., :r], causal=t > 1, scale=scale,
                    kv_valid=jnp.asarray(kv_valid) - seq_off,
                    prechunked=True, num_splits=1, return_state=True)
                acc, m_f, l_f = semantics.lse_merge_axis(
                    acc, m_f, l_f, tp.axis)
                o_lat = (acc / jnp.where(l_f == 0.0, 1.0, l_f)) \
                    .astype(q_full.dtype)
            else:
                splits = 1
                if t == 1:
                    splits = _resolve_splits(num_splits, rows=b,
                                             kv_len=tbl.shape[-1] * ps,
                                             page_size=ps)
                elif verify:
                    splits = _resolve_splits(num_splits, rows=b * h,
                                             kv_len=tbl.shape[-1] * ps,
                                             page_size=ps, mode="verify")
                o_lat = xla_flash(q_full, lat, lat[..., :r], causal=t > 1,
                                  scale=scale, kv_valid=kv_valid,
                                  prechunked=True, num_splits=splits)
    elif cfg.attn_impl == "tl_pallas":
        from ..kernels import ops
        if cache is not None and t == 1:
            # runtime-length decode: one compiled kernel per latent-cache
            # capacity; kv_valid (int / traced / per-row vector) is data
            o_lat = ops.mla_decode(q_full, latent, cache_len=kv_valid,
                                   num_splits=num_splits,
                                   kv_lora_rank=r, rope_head_dim=rr)
        else:
            lat = latent
            if kv_valid is not None:
                # cached prefill: only the first kv_valid latents are real
                lat = latent[:, :int(kv_valid)]
            o_lat = ops.mla_attention(q_full, lat, causal=causal,
                                      kv_lora_rank=r, rope_head_dim=rr)
    else:
        kk = latent[:, None]                     # (B, 1, N, R+Rr)
        vv = latent[:, None, :, :r]              # (B, 1, N, R)
        splits = 1
        if cache is not None and t == 1:
            splits = _resolve_splits(num_splits, rows=b,
                                     kv_len=kk.shape[2])
        o_lat = xla_flash(q_full, kk, vv, causal=causal, scale=scale,
                          kv_valid=kv_valid, chunk=cfg.attn_chunk,
                          num_splits=splits)
    o_lat = _constrain(o_lat, head_sharding)

    # --- un-absorb: latent out -> per-head values -> output proj -------------
    o = jnp.einsum("bhtr,rhv->bhtv", o_lat.astype(x.dtype), params["w_uv"])
    out = jnp.einsum("bhtv,hvd->btd", o, params["w_o"])
    return (out, cache) if cache is not None else (out, None)
