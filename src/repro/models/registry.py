"""Architecture registry + assigned input-shape sets.

``get_config(arch_id)`` / ``get_reduced(arch_id)`` look up the per-arch
config modules in ``repro.configs``; ``input_specs(cfg, shape_id)`` builds
the ShapeDtypeStruct stand-ins for every model input of one of the four
assigned shapes (no device allocation — the dry-run pattern).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-7b": "deepseek_7b",
    "llama3-405b": "llama3_405b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "musicgen-large": "musicgen_large",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def list_archs() -> list[str]:
    return list(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str, **overrides) -> ModelConfig:
    cfg = _module(arch_id).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_reduced(arch_id: str, **overrides) -> ModelConfig:
    cfg = _module(arch_id).reduced()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (assignment rule)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense-KV decode is the "
                       "quadratic-memory regime long_500k excludes "
                       "(DESIGN.md §Arch-applicability)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec | str,
                batch_override: Optional[int] = None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of (cfg, shape)."""
    from . import transformer  # local import to avoid cycles

    if isinstance(shape, str):
        shape = SHAPES[shape]
    b = batch_override or shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        specs = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.cross_attn_period:
            specs["vision_embeds"] = sds(
                (b, cfg.num_patches, cfg.vision_d), jnp.bfloat16)
        return specs

    if shape.kind == "prefill":
        specs = {"tokens": sds((b, s), i32)}
        if cfg.cross_attn_period:
            specs["vision_embeds"] = sds(
                (b, cfg.num_patches, cfg.vision_d), jnp.bfloat16)
        return specs

    if shape.kind == "decode":
        caches = jax.eval_shape(
            lambda: transformer.init_caches(cfg, b, s))
        return {"tokens": sds((b, 1), i32), "caches": caches,
                "cache_len": sds((), i32)}

    raise ValueError(shape.kind)


def build_model(cfg: ModelConfig):
    """Convenience bundle of the functional model API for one config."""
    from . import transformer

    return {
        "init": lambda key: transformer.init_params(key, cfg),
        "abstract_params": lambda: transformer.abstract_params(cfg),
        "apply": lambda p, tokens, **kw: transformer.apply(p, tokens, cfg, **kw),
        "loss_fn": lambda p, batch, **kw: transformer.loss_fn(p, batch, cfg, **kw),
        "init_caches": lambda b, n: transformer.init_caches(cfg, b, n),
        "config": cfg,
    }
