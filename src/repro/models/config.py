"""Unified model configuration covering all ten assigned architectures.

One dataclass; family-specific fields are inert for other families.  Every
field is static (hashable) so configs can key jit caches.
"""

from __future__ import annotations

import dataclasses

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_q_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- MoE ---------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0           # routed experts
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    moe_every: int = 1             # MoE FFN every Nth layer (1 = all)
    first_k_dense: int = 0         # leading dense-FFN layers (DeepSeek-V2)
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001

    # --- MLA (DeepSeek) ------------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = no query compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- hybrid (Jamba): 1 attention layer per `period`, rest Mamba ----------
    hybrid_period: int = 0         # 0 = not hybrid; Jamba = 8 (1:7)
    mamba_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4

    # --- ssm (RWKV-6) --------------------------------------------------------
    rwkv: bool = False
    rwkv_head_dim: int = 64

    # --- vlm: cross-attention every Nth layer consuming patch embeddings -----
    cross_attn_period: int = 0     # 0 = none; llama-3.2-vision = 5
    vision_d: int = 0              # patch embedding dim (stub frontend)
    num_patches: int = 0

    # pad query heads up to this count with zero-masked heads so the head
    # dim divides the 16-wide 'model' axis (e.g. coder-33b: 56 -> 64).
    # Padded heads are masked to zero before the output projection, so
    # semantics and gradients are exact; the cost is Hpad/H extra attention
    # FLOPs vs a 16x replication without it (EXPERIMENTS.md §Perf).
    pad_q_heads_to: int = 0

    # --- general --------------------------------------------------------------
    norm_eps: float = 1e-5
    rope_theta: float = 500000.0
    tie_embeddings: bool = False
    dtype: str = "bf16"            # params/activations dtype
    # attention implementation: "xla_flash" (chunked online-softmax, the
    # compile path for dry-runs), "tl_pallas" (TL-generated kernel,
    # interpret-mode on CPU), "naive" (reference einsum)
    attn_impl: str = "xla_flash"
    attn_chunk: int = 1024         # kv chunk for xla_flash
    remat: bool = True
    # remat policy: "nothing" (recompute all; min memory), "dots_nobatch"
    # (save GEMM outputs; min recompute)
    remat_policy: str = "nothing"
    # nested-scan (sqrt-depth) remat: scan G groups of periods with the
    # whole inner scan checkpointed, so only G + nper/G residual carries
    # are live instead of nper (llama3-405b: 126 -> 23 carries).  Costs one
    # extra forward recompute.  0 = flat scan.  Applies to the cache-free
    # (training) path only.
    remat_scan_groups: int = 0
    # max positions for RoPE tables etc.
    max_seq_len: int = 32768

    def __post_init__(self):
        if self.moe and not (self.num_experts and self.top_k):
            raise ValueError(f"{self.name}: moe requires num_experts/top_k")
        if self.family == "hybrid" and not self.hybrid_period:
            raise ValueError(f"{self.name}: hybrid requires hybrid_period")
        if self.num_q_heads % max(1, self.num_kv_heads):
            raise ValueError(f"{self.name}: Hq % Hkv != 0")

    @property
    def q_per_kv(self) -> int:
        return self.num_q_heads // max(1, self.num_kv_heads)

    @property
    def attention_free(self) -> bool:
        return self.rwkv

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k decode? (SSM/hybrid only.)"""
        return self.rwkv or self.hybrid_period > 0

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = 0
        if self.mla:
            dq = self.num_q_heads * (self.nope_head_dim + self.rope_head_dim)
            per_layer_attn += d * (self.q_lora_rank or d) if self.q_lora_rank else 0
            per_layer_attn += (self.q_lora_rank or d) * dq
            per_layer_attn += d * (self.kv_lora_rank + self.rope_head_dim)
            per_layer_attn += self.kv_lora_rank * self.num_q_heads * (
                self.nope_head_dim + self.v_head_dim)
            per_layer_attn += self.num_q_heads * self.v_head_dim * d
        elif not self.rwkv:
            hd = self.head_dim
            per_layer_attn += d * self.num_q_heads * hd
            per_layer_attn += 2 * d * self.num_kv_heads * hd
            per_layer_attn += self.num_q_heads * hd * d
        else:
            per_layer_attn += 5 * d * d + d * ff  # rwkv time-mix + channel-mix

        def ffn_params(hidden):
            return 3 * d * hidden  # SwiGLU

        n_attn_layers = self.num_layers
        n_moe = 0
        if self.moe:
            n_moe = self.num_layers // self.moe_every
        n_dense_ffn = self.num_layers - n_moe
        per_moe = (self.num_experts + self.num_shared_experts) * \
            ffn_params(self.moe_d_ff) + d * self.num_experts
        n += self.num_layers * per_layer_attn
        n += n_dense_ffn * ffn_params(ff) + n_moe * per_moe
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe = self.num_layers // self.moe_every
        per_moe_total = (self.num_experts + self.num_shared_experts) * \
            3 * d * self.moe_d_ff
        per_moe_active = (self.top_k + self.num_shared_experts) * \
            3 * d * self.moe_d_ff
        return int(full - n_moe * (per_moe_total - per_moe_active))
