from .pipeline import SyntheticTokens, batch_for_step  # noqa: F401
