"""Deterministic synthetic token pipeline.

Deterministic-by-step: ``batch_for_step(step)`` is a pure function of
(seed, step), so after a failure *any* host can regenerate *any* shard
without coordination — the property the fault-tolerance design relies on
(DESIGN.md §3.1: a restarted or replacement host picks up mid-run).

The token stream is a marked Markov-ish sequence (next token depends on the
previous token plus step-salted noise) rather than uniform noise, so a ~100M
model trained on it shows a real, monotonic loss drop (examples/train_100m).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # host sharding: this host produces rows [row_start, row_start+rows)
    row_start: int = 0
    rows: Optional[int] = None

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch(self, step: int) -> dict:
        rows = self.rows if self.rows is not None else self.global_batch
        rng = self._rng(step)
        # skip ahead to this host's rows deterministically
        full = rng.integers(0, self.vocab_size,
                            size=(self.global_batch, self.seq_len + 1),
                            dtype=np.int32)
        # inject learnable structure: token t+1 = f(token t) half the time
        follow = (full[:, :-1] * 31 + 7) % self.vocab_size
        gate = rng.random((self.global_batch, self.seq_len)) < 0.5
        full[:, 1:] = np.where(gate, follow, full[:, 1:])
        sl = slice(self.row_start, self.row_start + rows)
        return {"tokens": full[sl, :-1], "labels": full[sl, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def batch_for_step(step: int, *, vocab_size: int, seq_len: int,
                   global_batch: int, seed: int = 0) -> dict:
    return SyntheticTokens(vocab_size, seq_len, global_batch,
                           seed=seed).batch(step)
