"""QiMeng-Attention reproduction: TL-generated attention operators inside a
multi-pod JAX training/serving framework (see DESIGN.md)."""

__version__ = "0.1.0"
