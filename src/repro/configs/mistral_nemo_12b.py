"""Mistral-Nemo 12B — dense GQA kv=8, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407]  40L d=5120, 32/8 heads, head_dim
128, ff 14336, vocab 131072."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_q_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mistral-nemo-smoke", num_layers=2, d_model=64,
        num_q_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        head_dim=16, dtype="f32", max_seq_len=128)
