"""DeepSeek-Coder 33B — dense llama-arch GQA.  [arXiv:2401.14196; hf]
62L d=7168, 56 q heads / 8 kv heads (head_dim 128), ff 19200, vocab 32256."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_q_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128,
    rope_theta=100000.0,
    # 56 heads don't divide the 16-wide TP axis; pad to 64 zero-masked
    # heads (exact semantics) instead of replicating attention 16x --
    # EXPERIMENTS.md #Perf hillclimb A.
    pad_q_heads_to=64,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-coder-smoke", num_layers=2, d_model=64,
        num_q_heads=6, num_kv_heads=2, d_ff=128, vocab_size=512,
        head_dim=16, pad_q_heads_to=8, dtype="f32", max_seq_len=128)
