"""Jamba-1.5-Large (398B total / 94B active) — Mamba+attention 1:7 hybrid
with MoE every 2nd layer.  [arXiv:2403.19887; hf:ai21labs/AI21-Jamba-1.5-Large]
72L d=8192; attn layers GQA 64/8 (head_dim 128); MoE 16 experts top-2
(expert ff 24576); Mamba state 16, expand 2; vocab 65536.

Paper-technique applicability: the 9 attention layers use the TL-generated
flash kernel; the 63 Mamba layers are attention-free (chunked scan).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_q_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    hybrid_period=8, mamba_state=16, mamba_expand=2, mamba_conv=4,
    moe=True, num_experts=16, num_shared_experts=0, top_k=2,
    moe_d_ff=24576, moe_every=2,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="jamba-smoke", num_layers=4, d_model=64,
        num_q_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        head_dim=16, hybrid_period=4, mamba_state=8,
        num_experts=4, top_k=2, moe_d_ff=64, moe_every=2,
        dtype="f32", max_seq_len=128)
