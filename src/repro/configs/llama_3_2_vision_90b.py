"""Llama-3.2-Vision 90B — dense GQA backbone with cross-attention image
layers every 5th layer.  [hf:meta-llama/Llama-3.2-90B-Vision; unverified]
100L d=8192, 64/8 heads, ff 28672, vocab 128256.

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, num_patches, vision_d); the cross-attn
layers consume them directly.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_q_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    cross_attn_period=5, vision_d=1280, num_patches=1600,
    rope_theta=500000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama-vision-smoke", num_layers=5, d_model=64,
        num_q_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        head_dim=16, cross_attn_period=5, vision_d=32, num_patches=16,
        dtype="f32", max_seq_len=128)
