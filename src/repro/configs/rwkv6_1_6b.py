"""RWKV-6 "Finch" 1.6B — attention-free linear recurrence with
data-dependent decay.  [arXiv:2404.05892; unverified]
24L d=2048, ff 7168 (channel-mix), vocab 65536, head_dim 64.

Paper-technique applicability: NONE (attention-free) — implemented with
the chunked linear-scan kernel instead; noted in DESIGN.md.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_q_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536, head_dim=64,
    rwkv=True, rwkv_head_dim=64,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="rwkv6-smoke", num_layers=2, d_model=64,
        num_q_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
        head_dim=16, rwkv_head_dim=16, dtype="f32", max_seq_len=128)
