"""Qwen3-MoE 235B-A22B — GQA kv=4, 128 routed experts top-8, no shared.

[hf:Qwen/Qwen3-235B-A22B (per-assignment hf:Qwen/Qwen3-30B-A3B family)]
94L d=4096, 64 q heads / 4 kv heads, head_dim 128, expert ff 1536,
vocab 151936.  All layers MoE.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_q_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    moe=True, num_experts=128, num_shared_experts=0, top_k=8,
    moe_d_ff=1536, moe_every=1,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-smoke", num_layers=2, d_model=64,
        num_q_heads=8, num_kv_heads=2, d_ff=96, vocab_size=512, head_dim=16,
        num_experts=8, top_k=2, moe_d_ff=96, dtype="f32", max_seq_len=128)
