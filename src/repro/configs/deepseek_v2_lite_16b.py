"""DeepSeek-V2-Lite (15.7B total / 2.4B active) — MLA + fine-grained MoE.

[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite]  27L d=2048, 16 heads,
MLA kv_lora=512 rope=64 (no q compression in Lite), MoE 64 routed top-6 +
2 shared (expert ff 1408), first layer dense (ff 10944), vocab 102400.
The assignment header's "160 routed" is the V2-full figure; the bracketed
Lite source values are used (DESIGN.md §Arch-applicability).

This is the paper's own MLA arch (Table 2 workload).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_q_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, head_dim=128,
    mla=True, kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
    nope_head_dim=128, v_head_dim=128,
    moe=True, num_experts=64, num_shared_experts=2, top_k=6,
    moe_d_ff=1408, moe_every=1, first_k_dense=1,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-v2-lite-16b-smoke", num_layers=3, d_model=64,
        num_q_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        head_dim=16, kv_lora_rank=32, rope_head_dim=16, nope_head_dim=16,
        v_head_dim=16, num_experts=8, top_k=2, num_shared_experts=1,
        moe_d_ff=32, first_k_dense=1, dtype="f32", max_seq_len=128)
