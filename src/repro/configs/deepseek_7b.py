"""DeepSeek-LLM 7B — dense llama-arch MHA.  [arXiv:2401.02954; hf]
30L d=4096, 32 heads (kv=32 -> MHA), ff 11008, vocab 102400."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_q_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400, head_dim=128,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-7b-smoke", num_layers=2, d_model=64,
        num_q_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        head_dim=16, dtype="f32", max_seq_len=128)
