"""MusicGen-Large — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf:facebook/musicgen-large]  48L d=2048, 32 MHA heads
(head_dim 64), ff 8192, vocab 2048 (EnCodec codebook).

Modality frontend is a STUB per the assignment: ``input_specs()`` provides
token ids in the EnCodec code space (the audio tokenizer is out of scope);
the backbone is the deliverable.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_q_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="musicgen-smoke", num_layers=2, d_model=64,
        num_q_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
        head_dim=16, dtype="f32", max_seq_len=128)
