"""One module per assigned architecture; each exports CONFIG (the exact
published geometry) and reduced() (a same-family small config for CPU smoke
tests).  See repro.models.registry for lookup."""

ARCH_IDS = [
    "deepseek-v2-lite-16b",
    "qwen3-moe-235b-a22b",
    "deepseek-7b",
    "llama3-405b",
    "mistral-nemo-12b",
    "deepseek-coder-33b",
    "musicgen-large",
    "llama-3.2-vision-90b",
    "jamba-1.5-large-398b",
    "rwkv6-1.6b",
]
