"""Llama-3.1 405B — largest dense; GQA kv=8, 128k vocab.
[arXiv:2407.21783; unverified]  126L d=16384, 128 q heads / 8 kv heads,
ff 53248, vocab 128256."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_q_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128,
    rope_theta=500000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama3-405b-smoke", num_layers=3, d_model=64,
        num_q_heads=8, num_kv_heads=2, d_ff=192, vocab_size=512,
        head_dim=16, dtype="f32", max_seq_len=128)
