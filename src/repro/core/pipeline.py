"""End-to-end TL workflow — the paper's Figure 3 as a function.

``generate_attention_kernel(spec, q_len, kv_len)`` runs:

  1. *TL Sketch generation* (backend; deterministic by default),
  2. *Parameter analysis & reasoning* (+ the analytic autotuner for block
     sizes — the self-optimizing loop),
  3. *validation* (statement-level checks; Appendix-B failure modes),
  4. *translation* to both backends: the Pallas TPU kernel and the pure-jnp
     oracle.

The returned :class:`GeneratedKernel` carries every intermediate artifact
(sketch text, TL code text, diagnostics, block config) so tests, benchmarks
and docs can show the whole derivation — the paper's Figure 1(c) pipeline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

from . import autotune
from .llm import DeterministicBackend, GeneratorBackend
from .reason import BlockConfig
from .spec import AttnSpec
from .target import TPUTarget, get_target
from .tl.ast import TLProgram
from .tl.parser import parse
from .tl.validator import Diagnostic, check, validate
from .translate.jnp_backend import translate_jnp
from .translate.pallas_backend import translate_pallas


@dataclasses.dataclass
class GeneratedKernel:
    spec: AttnSpec
    q_len: int
    kv_len: int
    target: TPUTarget
    blocks: BlockConfig
    sketch_text: str
    tl_text: str
    program: TLProgram
    diagnostics: list[Diagnostic]
    pallas_fn: Callable                 # batched (B, H, M, D) kernel
    oracle_fn: Callable                 # single-head 2-D oracle
    tune: Optional[autotune.TuneResult]
    num_splits: int = 1                 # reasoned (clamped) KV split count

    def __call__(self, *args):
        return self.pallas_fn(*args)


def generate_attention_kernel(
    spec: AttnSpec,
    q_len: int,
    kv_len: int,
    *,
    target: TPUTarget | str = "v5e",
    backend: Optional[GeneratorBackend] = None,
    blocks: Optional[BlockConfig] = None,
    num_splits: Optional[int] = None,
    interpret: bool = True,
    causal_block_skip: bool = True,
    strict: bool = True,
    shard_axis: Optional[str] = None,
) -> GeneratedKernel:
    """Generate a fused attention kernel for ``spec`` via the TL workflow.

    ``num_splits`` is the split-KV work-partitioning request (decode mode;
    Flash-Decoding) — ``None``/1 keeps the sequential KV loop; larger
    values are clamped by the reasoning stage (see
    :func:`repro.core.reason.split_layout`) and lowered by both backends
    as parallel KV partitions plus an LSE-merge combine.

    ``shard_axis``: named mesh axis for sequence-sharded execution inside
    ``shard_map`` — the Pallas backend all-gathers the per-rank partial
    online-softmax states into its LSE-merge combine (tensor-parallel
    serving's cross-shard reduction)."""

    if isinstance(target, str):
        target = get_target(target)
    backend = backend or DeterministicBackend()

    # decode attends to the whole cache — no causal masking inside the tile
    sketch_spec = spec
    if spec.mode == "decode" and spec.causal:
        sketch_spec = dataclasses.replace(spec, causal=False)

    tr = None
    if blocks is None:
        tr = autotune.tune(sketch_spec, q_len, kv_len, target)
        blocks = tr.blocks

    # Stage 1a: sketch (text — the LLM exchange format)
    sketch_text = backend.generate_sketch(sketch_spec)

    # Stage 1b: parameter reasoning -> complete TL code (text)
    tl_text = backend.reason_parameters(
        sketch_text, sketch_spec, q_len, kv_len, target, blocks,
        num_splits=num_splits)

    # Parse + validate (per-statement checking is what makes the paper's
    # workflow reliable; E-diagnostics abort translation)
    prog = parse(tl_text, name=f"{spec.variant}_{spec.mode}")
    # re-attach the parameter environment (text comments carry it for humans;
    # the authoritative binding comes from the reasoning stage)
    reasoned = _reparse_params(sketch_spec, q_len, kv_len, target, blocks,
                               backend, num_splits)
    prog.params = reasoned.params
    prog.inputs = reasoned.inputs
    prog.outputs = ("O",)
    prog.meta = dict(reasoned.meta)
    diags = validate(prog, target)
    if strict:
        check(prog, target)
    # the reasoning stage may have re-aligned the blocks (paged decode
    # clamps BN to the page size); the reasoned config is authoritative
    blocks = prog.meta.get("blocks", blocks)

    pallas_fn = translate_pallas(
        prog, interpret=interpret, causal_block_skip=causal_block_skip,
        shard_axis=shard_axis)
    oracle_fn = translate_jnp(prog)

    return GeneratedKernel(
        spec=spec, q_len=q_len, kv_len=kv_len, target=target, blocks=blocks,
        sketch_text=sketch_text, tl_text=tl_text, program=prog,
        diagnostics=diags, pallas_fn=pallas_fn, oracle_fn=oracle_fn, tune=tr,
        num_splits=int(prog.meta.get("num_splits", 1)))


def _reparse_params(spec, q_len, kv_len, target, blocks, backend,
                    num_splits=None):
    """Recover the authoritative parameter binding for the parsed text.

    The deterministic backend can hand us the AST directly; an LLM backend
    only exchanges text, so parameters are re-derived through the same
    reasoning entry point (they are a pure function of spec/shape/blocks).
    """
    from .reason import reason_parameters
    from .sketch import generate_sketch

    return reason_parameters(generate_sketch(spec), spec, q_len=q_len,
                             kv_len=kv_len, target=target, blocks=blocks,
                             num_splits=num_splits)


@functools.lru_cache(maxsize=256)
def cached_kernel(spec: AttnSpec, q_len: int, kv_len: int,
                  target_name: str = "v5e", interpret: bool = True,
                  causal_block_skip: bool = True,
                  num_splits: int = 1,
                  shard_axis: Optional[str] = None) -> GeneratedKernel:
    """lru-cached kernel factory used by the model layer.

    Keyed on the *requested* ``num_splits`` (and the shard axis, for
    sequence-sharded serving) — one compiled kernel per (spec, shape
    bucket, splits, mesh axis), the serving compile contract."""
    return generate_attention_kernel(
        spec, q_len, kv_len, target=target_name, interpret=interpret,
        causal_block_skip=causal_block_skip, num_splits=num_splits,
        shard_axis=shard_axis)
