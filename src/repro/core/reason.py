"""Stage 1b — Parameter Analysis and Reasoning (paper §3.2.2).

Takes a TL *Sketch* and produces complete *TL Code* by

  1. allocating every global tensor the copies refer to (``Allocate ... in
     global (M, HeadDim) with offset bh``),
  2. expanding each ``Copy`` with its block shape and tile coordinate
     (``Copy K (BN, HeadDim) in coordinate [L = i] from global to shared``),
  3. declaring the register-tier intermediates (accumulator, online-softmax
     running max/denominator, score tile),
  4. inserting the **Reshape** between the two fused GEMMs — the paper's
     critical fusion statement (mma_C -> mma_A on Tensor Cores; on the MXU
     the f32 accumulator tile must be re-declared/cast as an input-dtype
     operand tile), and
  5. binding the symbolic parameter environment (M, N, BM, BN, Tkv, ...).

``omit_reshape=True`` / ``gemm_layout_bug=True`` reproduce the paper's
Appendix-B one-stage failure modes (Listing 1 / Listing 2) so the validator
tests can demonstrate they are caught.
"""

from __future__ import annotations

import copy
import dataclasses
import math
from typing import Optional

from .spec import AttnSpec
from .target import TPUTarget, get_target
from .tl.ast import (
    Allocate,
    ComputeGEMM,
    ComputeOp,
    Copy,
    ForLoop,
    MemSpace,
    Reshape,
    Statement,
    TLProgram,
)

LANE = 128

# Split-KV decode (Flash-Decoding) cap: each extra split adds a partial
# (acc, m, l) tile to merge in the combine stage, so past this the combine
# overhead eats the parallelism win on every target we describe.
MAX_KV_SPLITS = 8


class ReasonError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Block-size decision produced here or by the autotuner."""

    bm: int
    bn: int

    def as_params(self) -> dict:
        return {"BM": self.bm, "BN": self.bn}


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def default_blocks(spec: AttnSpec, q_len: int, kv_len: int,
                   target: TPUTarget) -> BlockConfig:
    """MXU/VMEM-aware default blocking (the reasoning stage's napkin math).

    BM/BN want to be MXU-aligned (128) and the working set
    ``BM*Dqk + 2*BN*(Dqk+Dv) + BM*BN + BM*Dv`` (bf16/f32 mix, double-buffered
    KV) must fit the VMEM budget.  For short sequences shrink to the padded
    length instead of wasting compute on padding.
    """

    sub = 8  # f32 sublane; accumulators are f32
    bm = min(_round_up(q_len, sub), 128 if spec.qk_dim > 256 else 256)
    bn = min(_round_up(kv_len, LANE), 512)
    while _vmem_bytes(spec, bm, bn) > target.vmem_budget and bn > LANE:
        bn //= 2
    while _vmem_bytes(spec, bm, bn) > target.vmem_budget and bm > sub:
        bm //= 2
    return BlockConfig(bm=bm, bn=bn)


def split_layout(num_splits: int, tkv: int, unit: int = 1) -> tuple[int, int]:
    """Clamp a requested KV split count to the tile grid.

    Returns ``(ns, tps)``: ``ns`` splits of ``tps`` KV tiles each (the last
    split may cover fewer live tiles).  Splits are whole-tile, at most
    :data:`MAX_KV_SPLITS` (the combine-overhead bound applies to forced
    requests too), and ``unit`` (KV tiles per page in a paged layout)
    keeps every split boundary on a page boundary so a split's gather
    never touches a partial page.  The result is a fixed point:
    ``split_layout(ns, tkv, unit) == (ns, tps)`` again, which is what
    lets reason record the final ``NUM_SPLITS`` and both translation
    backends re-derive the identical layout.
    """
    unit = max(1, int(unit))
    tkv = max(1, int(tkv))
    ns = max(1, min(int(num_splits), tkv, MAX_KV_SPLITS))
    tps = -(-tkv // ns)                 # tiles per split, then page-align up
    tps = -(-tps // unit) * unit
    ns = -(-tkv // tps)
    return ns, tps


def choose_num_splits(*, rows: int, kv_len: int, mode: str = "decode",
                      page_size: Optional[int] = None,
                      target: TPUTarget | str = "v5e",
                      shards: int = 1) -> int:
    """The reasoning stage's split-KV decision (Flash-Decoding; FA-2's
    "parallelism and work partitioning" axis).

    Decode grids expose only ``rows = bsz * heads`` parallel programs while
    the KV axis rides the sequential grid dimension — a small continuous-
    batching batch over a long context leaves the device idle.  The
    decision is the autotuner's scored search
    (:func:`repro.core.autotune.tune_splits`): every legal split count —
    whole pages (paged) / lane tiles (dense), at most
    :data:`MAX_KV_SPLITS` — is costed as waves of ``rows * splits``
    programs against the target's calibrated ``decode_parallelism`` plus
    per-split LSE-combine overhead, and the cheapest critical path wins.
    Deterministic: a pure function of (mode, rows, bucketed KV length,
    page geometry, target).

    ``verify`` mode (speculative-decode verification) consults the same
    scoring — a K-token verify program has decode's shape problem (few
    rows, long cache); prefill modes never split (they already parallelise
    over q tiles).

    ``shards`` is the model-axis width when serving on a mesh: each shard
    dispatches ``ceil(rows / shards)`` of the head rows, so the wave count
    is scored against the per-shard launch width — wider meshes want more
    KV splitting to stay full.
    """
    if mode not in ("decode", "verify"):
        return 1
    if isinstance(target, str):
        target = get_target(target)
    from . import autotune  # lazy: autotune imports reason's block machinery

    return int(autotune.tune_splits(rows=rows, kv_len=kv_len,
                                    page_size=page_size,
                                    target=target,
                                    shards=shards).num_splits)


def resolve_num_splits(num_splits: Optional[int], *, rows: int, kv_len: int,
                       mode: str = "decode",
                       page_size: Optional[int] = None,
                       target: TPUTarget | str = "v5e",
                       shards: int = 1) -> int:
    """A caller's explicit split request, or the heuristic default.

    The single resolution point for every lowering (TL/Pallas, jnp
    oracle, XLA scan): one decision, N lowerings.  Explicit requests are
    honoured up to :data:`MAX_KV_SPLITS` — the combine-overhead cap is a
    property of the lowering, not of who asked.  ``shards`` (model-axis
    mesh width) rescales the heuristic's launch width only; explicit
    requests are already a per-shard statement."""
    if num_splits is not None:
        return max(1, min(int(num_splits), MAX_KV_SPLITS))
    return choose_num_splits(rows=rows, kv_len=kv_len, mode=mode,
                             page_size=page_size, target=target,
                             shards=shards)


def _vmem_bytes(spec: AttnSpec, bm: int, bn: int) -> int:
    in_b = 2 if spec.dtype in ("bf16", "f16", "fp8") else 4
    q = bm * spec.qk_dim * in_b
    kv = 2 * bn * (spec.qk_dim + spec.v_dim) * in_b  # double-buffered K,V
    s = bm * bn * 4
    acc = bm * spec.v_dim * 4
    ml = 2 * bm * LANE * 4
    return q + kv + s + acc + ml


# ---------------------------------------------------------------------------


def reason_parameters(
    sketch: TLProgram,
    spec: AttnSpec,
    *,
    q_len: int,
    kv_len: int,
    target: TPUTarget | str = "v5e",
    blocks: Optional[BlockConfig] = None,
    num_splits: Optional[int] = None,
    omit_reshape: bool = False,
    gemm_layout_bug: bool = False,
) -> TLProgram:
    """Expand a TL Sketch into complete TL Code (see module docstring).

    ``num_splits`` (decode mode only) is the split-KV work-partitioning
    decision: the KV loop is divided into that many *parallel* partitions,
    each producing partial ``(acc, m, l)`` online-softmax state that an
    LSE-merge combine stage reduces (Flash-Decoding).  The request is
    clamped through :func:`split_layout` (whole tiles, page-aligned in
    paged layouts) and the final count is recorded as the ``NUM_SPLITS``
    parameter (with the ``KV_SPLIT`` marker) for both translation
    backends.  ``None``/1 keeps the single sequential KV loop."""

    if isinstance(target, str):
        target = get_target(target)
    if blocks is None:
        blocks = default_blocks(spec, q_len, kv_len, target)

    mla = spec.variant == "mla"
    dq_sym = "Dq" if mla else "HeadDim"   # score-GEMM contraction width
    dv_sym = "R" if mla else "HeadDim"    # value width

    # Decode programs are runtime-length: ``N`` binds the *bucket capacity*
    # (the compiled KV extent) and the true cache length enters the kernel
    # as a scalar operand at call time.  One compiled kernel then serves
    # every cache length within the bucket — the FlashDecoding-style
    # serving contract — instead of one kernel per decode step.
    #
    # Chunked-prefill programs are runtime-length too, but the scalar is
    # the *history length*: M chunk tokens sit at runtime positions
    # hist..hist+M-1, so the causal diagonal is shifted by the scalar and
    # one compiled kernel serves every chunk position within the bucket.
    # Verify programs (speculative decode) are chunked-prefill geometry —
    # K+1 candidate tokens at runtime positions hist..hist+K — with decode's
    # work-partitioning problem (few rows, long cache), so they may carry a
    # split-KV layout on top of the chunk tiling.
    chunked = spec.mode in ("chunk_prefill", "verify")
    runtime_kv = spec.mode == "decode" or chunked

    # Paged decode layout: the KV cache is a pool of PAGE_SIZE-token pages
    # and a second runtime operand — the per-request block table — selects
    # which physical page holds each logical KV tile.  The page size is a
    # reasoned block parameter: BN is aligned down so every KV tile lies
    # inside exactly one page (a tile must never straddle a page boundary,
    # or the gather would need two DMAs per tile).
    paged = spec.paged
    if paged:
        page = spec.page_size
        if kv_len % page:
            raise ReasonError(
                f"paged decode capacity N={kv_len} must be a multiple of "
                f"page_size={page} (the block table addresses whole pages)")
        bn = blocks.bn
        if bn > page:
            bn = page
        if page % bn:
            bn = math.gcd(page, bn)
        if bn != blocks.bn:
            blocks = BlockConfig(bm=blocks.bm, bn=bn)

    # Split-KV (Flash-Decoding): partition the KV loop into NUM_SPLITS
    # parallel pieces.  A reasoned decision like BN/PAGE_SIZE: the request
    # is clamped to whole KV tiles and (paged) whole pages per split, so
    # the translated gather/mask machinery is untouched inside a split.
    splits = 1
    if num_splits is not None and int(num_splits) != 1:
        if spec.mode not in ("decode", "verify"):
            raise ReasonError(
                f"KV split is a decode work-partitioning decision; mode "
                f"{spec.mode!r} parallelises over q tiles instead")
        want = min(int(num_splits), MAX_KV_SPLITS)
        if not paged:
            # partitioning feeds back into tiling (the FA-2 observation):
            # a KV tile as wide as the whole bucket leaves nothing to
            # split, so shrink BN — never below a lane tile — until the
            # KV axis has enough tiles to honour the request.  (Paged
            # layouts can't gain tiles this way: splits are clamped to
            # whole pages, and shrinking BN never adds pages.)
            bn = blocks.bn
            while -(-kv_len // bn) < want and bn > LANE and bn % 2 == 0:
                bn //= 2
            if bn != blocks.bn:
                blocks = BlockConfig(bm=blocks.bm, bn=bn)
        unit = spec.page_size // blocks.bn if paged else 1
        splits, _ = split_layout(int(num_splits),
                                 -(-kv_len // blocks.bn), unit)

    params: dict = {
        "M": q_len,
        "N": kv_len,
        "BM": blocks.bm,
        "BN": blocks.bn,
        "Tkv": -(-kv_len // blocks.bn),
        "LANE": LANE,
        # bottom-right causal alignment (FA-2); chunked prefill aligns at
        # run time instead — the history-length scalar IS the offset
        "QOFF": 0 if chunked else kv_len - q_len,
        "sm_scale": spec.scale(),
    }
    if runtime_kv:
        # marker visible to both translation backends (and to the TL text
        # round-trip, which re-derives params through this function)
        params["KV_RUNTIME"] = 1
    if chunked:
        params["KV_CHUNK"] = 1
    if paged:
        params["KV_PAGED"] = 1
        params["PAGE_SIZE"] = spec.page_size
    if spec.kv_dtype is not None:
        # marker: the KV pool holds quantized values; one f32 absmax scale
        # per page rides the scalar-prefetch tier next to the block table
        # and the backends dequantize at tile materialization, before QK^T
        params["KV_QUANT"] = 1
    if splits > 1:
        # marker + final (clamped) split count; the backends re-derive the
        # identical per-split tile layout through split_layout
        params["KV_SPLIT"] = 1
        params["NUM_SPLITS"] = splits
    if mla:
        params["R"] = spec.kv_lora_rank
        params["Rr"] = spec.rope_head_dim
        params["Dq"] = spec.kv_lora_rank + spec.rope_head_dim
    else:
        params["HeadDim"] = spec.head_dim
    if spec.window is not None:
        params["W"] = spec.window

    body = copy.deepcopy(sketch.body)

    # (1)+(3) allocations ----------------------------------------------------
    # Quantized pages change only the *cache* allocations (K/V, MLA's C):
    # Q and O keep the spec dtype, and the register tier is f32 as always —
    # the dequant happens at tile materialization inside the KV loop.
    kv_dt = spec.kv_dtype or spec.dtype
    allocs: list[Statement] = []
    if mla:
        allocs += [
            Allocate("Q", MemSpace.GLOBAL, ("M", dq_sym), spec.dtype, offset="bh"),
            Allocate("C", MemSpace.GLOBAL, ("N", dq_sym), kv_dt, offset="b"),
        ]
    else:
        allocs += [
            Allocate("Q", MemSpace.GLOBAL, ("M", dq_sym), spec.dtype, offset="bh"),
            Allocate("K", MemSpace.GLOBAL, ("N", dq_sym), kv_dt, offset="bh_kv"),
            Allocate("V", MemSpace.GLOBAL, ("N", dv_sym), kv_dt, offset="bh_kv"),
        ]
    allocs += [
        Allocate("O", MemSpace.GLOBAL, ("M", dv_sym), spec.dtype, offset="bh"),
        Allocate("acc", MemSpace.REGISTER, ("BM", dv_sym), "f32"),
        Allocate("m", MemSpace.REGISTER, ("BM", "LANE"), "f32"),
        Allocate("l", MemSpace.REGISTER, ("BM", "LANE"), "f32"),
        Allocate("S", MemSpace.REGISTER, ("BM", "BN"), "f32"),
    ]

    # (2) copy expansion -----------------------------------------------------
    def _expand(stmts: list[Statement], loop_var: Optional[str]) -> None:
        for idx, s in enumerate(stmts):
            if isinstance(s, ForLoop):
                _expand(s.body, s.var)
                continue
            if not isinstance(s, Copy):
                continue
            coord = loop_var if loop_var is not None else "q"
            if s.name == "Q":
                stmts[idx] = Copy("Q", s.src, s.dst, ("BM", dq_sym), {"L": "q"})
            elif s.name in ("K", "C"):
                stmts[idx] = Copy(s.name, s.src, s.dst, ("BN", dq_sym), {"L": coord})
            elif s.name == "V":
                stmts[idx] = Copy("V", s.src, s.dst, ("BN", dv_sym), {"L": coord})
            elif s.name == "O":
                stmts[idx] = Copy("O", s.src, s.dst, ("BM", dv_sym), {"L": "q"})
            else:
                raise ReasonError(f"sketch copies unknown tensor {s.name!r}")

    _expand(body, None)

    # (4) reshape insertion between fused GEMMs ------------------------------
    # Find, inside each loop body, a GEMM whose A-operand is produced by an
    # earlier compute chained from a previous GEMM, and insert the layout
    # re-declaration the MXU fusion requires.
    def _insert_reshape(stmts: list[Statement]) -> None:
        for s in stmts:
            if isinstance(s, ForLoop):
                _insert_reshape(s.body)
        produced_by_gemm: set[str] = set()
        i = 0
        while i < len(stmts):
            s = stmts[i]
            if isinstance(s, ComputeGEMM):
                if s.a.name in produced_by_gemm and not omit_reshape:
                    stmts.insert(i, Reshape(s.a.name, "mma_C", "mma_A"))
                    i += 1
                produced_by_gemm.add(s.out)
            elif isinstance(s, ComputeOp) and s.out:
                if any(a in produced_by_gemm for a in s.args):
                    produced_by_gemm.add(s.out)
            i += 1

    _insert_reshape(body)

    if gemm_layout_bug:
        # Appendix-B Listing 2: drop the formal transpose notation on K.
        for s in TLProgram("tmp", body).walk():
            if isinstance(s, ComputeGEMM) and s.b.transposed:
                object.__setattr__(s.b, "transposed", False)

    prog = TLProgram(
        name=sketch.name.replace("_sketch", "") + "_tl",
        body=allocs + body,
        params=params,
        inputs=tuple(a.name for a in allocs
                     if a.space is MemSpace.GLOBAL and a.name != "O"),
        outputs=("O",),
        meta={**sketch.meta, "stage": "code", "blocks": blocks,
              "target": target.name, "runtime_kv_len": runtime_kv,
              "paged": paged, "chunk_prefill": chunked,
              "num_splits": splits, "kv_quant": spec.kv_dtype is not None},
    )
    return prog
