"""Attention operator specifications — the *user requirement* input to the
paper's workflow (Figure 3: "User Requirements" -> TL Sketch).

An :class:`AttnSpec` describes *what* attention operator is wanted (variant,
head geometry, masking, mode); the TL pipeline decides *how* (blocking,
fusion, online softmax) and the translation backend decides the low-level
realisation.  This mirrors the paper's separation of optimization logic from
implementation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

VARIANTS = ("mha", "gqa", "mqa", "mla")
MODES = ("full", "decode", "chunk_prefill", "verify")


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    variant: str = "mha"
    num_q_heads: int = 16
    num_kv_heads: int = 16
    head_dim: int = 128
    causal: bool = True
    window: Optional[int] = None       # sliding-window size (None = global)
    mode: str = "full"  # "full" | "decode" | "chunk_prefill" | "verify"
    # MLA-only geometry (DeepSeek-V2/V3): latent KV rank + decoupled RoPE dim
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    dtype: str = "bf16"
    sm_scale: Optional[float] = None
    # Paged KV layout (decode / chunk_prefill).  None = dense runtime-length
    # cache; an int = the cache is a pool of fixed-size pages of this many
    # tokens, gathered through a per-request block table at run time.  The
    # page size is a *reasoned* block parameter: the reasoning stage aligns
    # the KV block size BN to it so every KV tile lives inside one page.
    #
    # ``chunk_prefill`` is the paged prefill mode: M tokens of one prompt
    # chunk attend causally to the block-table pages already written (the
    # prefix history) plus the chunk itself.  The history length is a
    # *runtime* per-row scalar — it shifts the causal diagonal — so one
    # compiled kernel serves every chunk position within a bucket.
    #
    # ``verify`` is the speculative-decode verification mode: K+1 candidate
    # tokens (the committed token plus K drafts) attend causally to the
    # paged history, exactly the chunk_prefill geometry but with decode-like
    # M (a handful of rows) — so reason may additionally partition the KV
    # axis split-KV style (``num_splits``) when the cache is long, which
    # chunk_prefill never does.
    page_size: Optional[int] = None
    # Quantized KV page storage.  None = pages hold ``dtype`` values;
    # "int8" = pages hold symmetric int8 values with one f32 absmax scale
    # per *page* riding the scalar-prefetch tier next to the block table.
    # Dequantization happens inside the KV inner loop of every backend
    # (scale gather + cast before the QK^T tile), so Q/O and all compute
    # stay in ``dtype``/f32 — only the cache residency shrinks.
    kv_dtype: Optional[str] = None

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"variant {self.variant!r} not in {VARIANTS}")
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.mode in ("chunk_prefill", "verify"):
            if self.page_size is None:
                raise ValueError(f"{self.mode} is a paged mode — it needs "
                                 "page_size (dense prefill uses "
                                 "mode='full')")
            if not self.causal:
                raise ValueError(f"{self.mode} is causal by construction "
                                 "(the chunk extends the sequence)")
            if self.window is not None:
                raise ValueError(f"{self.mode} does not support sliding "
                                 "windows (the runtime history offset and "
                                 "the static window mask would conflict)")
        if self.page_size is not None:
            if self.mode not in ("decode", "chunk_prefill", "verify"):
                raise ValueError("paged KV layout (page_size) is a decode/"
                                 "chunk-prefill/verify cache contract; "
                                 "train specs are dense")
            if self.page_size <= 0 or self.page_size % 8:
                raise ValueError(f"page_size {self.page_size} must be a "
                                 "positive multiple of the f32 sublane (8)")
        if self.kv_dtype is not None:
            if self.kv_dtype != "int8":
                raise ValueError(f"kv_dtype {self.kv_dtype!r} unsupported; "
                                 "only 'int8' quantized pages are lowered")
            if self.page_size is None:
                raise ValueError("kv_dtype is a paged-cache contract (the "
                                 "scale table rides the block table); set "
                                 "page_size")
        if self.variant == "mha" and self.num_q_heads != self.num_kv_heads:
            raise ValueError("MHA requires num_q_heads == num_kv_heads")
        if self.variant == "mqa" and self.num_kv_heads != 1:
            raise ValueError("MQA requires num_kv_heads == 1")
        if self.variant == "gqa" and self.num_q_heads % self.num_kv_heads:
            raise ValueError("GQA requires num_q_heads % num_kv_heads == 0")

    @property
    def paged(self) -> bool:
        """True when the decode KV cache is a page pool + block table."""
        return self.page_size is not None

    @property
    def q_per_kv(self) -> int:
        """Query heads per KV head (GQA group size; 1 for MHA)."""
        if self.variant == "mla":
            return self.num_q_heads
        return self.num_q_heads // self.num_kv_heads

    @property
    def qk_dim(self) -> int:
        """Contraction width of the score GEMM."""
        if self.variant == "mla":
            return self.kv_lora_rank + self.rope_head_dim
        return self.head_dim

    @property
    def v_dim(self) -> int:
        """Width of the value operand of the second GEMM."""
        if self.variant == "mla":
            return self.kv_lora_rank
        return self.head_dim

    def scale(self) -> float:
        if self.sm_scale is not None:
            return self.sm_scale
        if self.variant == "mla":
            # DeepSeek scales by the *pre-absorption* per-head qk dim
            # (qk_nope_head_dim + rope dim = 128 + 64 in V2/V3).
            return 1.0 / math.sqrt(128 + self.rope_head_dim)
        return 1.0 / math.sqrt(self.head_dim)

    # convenience constructors ------------------------------------------------
    @staticmethod
    def mha(heads: int = 16, head_dim: int = 128, **kw) -> "AttnSpec":
        return AttnSpec(variant="mha", num_q_heads=heads, num_kv_heads=heads,
                        head_dim=head_dim, **kw)

    @staticmethod
    def gqa(q_heads: int, kv_heads: int, head_dim: int = 128, **kw) -> "AttnSpec":
        return AttnSpec(variant="gqa", num_q_heads=q_heads,
                        num_kv_heads=kv_heads, head_dim=head_dim, **kw)

    @staticmethod
    def mqa(q_heads: int, head_dim: int = 128, **kw) -> "AttnSpec":
        return AttnSpec(variant="mqa", num_q_heads=q_heads, num_kv_heads=1,
                        head_dim=head_dim, **kw)

    @staticmethod
    def mla(q_heads: int = 128, kv_lora_rank: int = 512,
            rope_head_dim: int = 64, **kw) -> "AttnSpec":
        kw.setdefault("head_dim", 128)
        return AttnSpec(variant="mla", num_q_heads=q_heads, num_kv_heads=1,
                        kv_lora_rank=kv_lora_rank, rope_head_dim=rope_head_dim,
                        **kw)

    def attention_flops(self, batch: int, q_len: int, kv_len: int) -> float:
        """Paper's FLOP convention: 4 * seq^2 * head_dim * heads (2 GEMMs)."""
        per_head = 2.0 * q_len * kv_len * (self.qk_dim + self.v_dim)
        total = batch * self.num_q_heads * per_head
        if self.causal and self.mode == "full" and q_len == kv_len:
            total *= 0.5
        return total
