"""Generator backends for the 2-stage TL workflow.

The paper drives both stages with an LLM prompted by the Listings-3/4
prompts.  This container is offline, so the default backend is the
deterministic rule engine (:mod:`repro.core.sketch` / :mod:`repro.core
.reason`) — see DESIGN.md assumption A1.  The interface is text-in/text-out
TL, exactly the artifact an LLM produces, so a hosted-model backend drops in
without touching the validator or translators.

``OneStageBackend`` reproduces the paper's Appendix-B ablation: it skips the
sketch stage and emits TL code directly, manifesting the reshape-omission /
GEMM-layout failure modes that the validator then rejects.
"""

from __future__ import annotations

from typing import Protocol

from .reason import BlockConfig, reason_parameters
from .sketch import generate_sketch, generate_sketch_text
from .spec import AttnSpec
from .target import TPUTarget
from .tl.parser import parse


class GeneratorBackend(Protocol):
    """The two LLM-driven steps of the paper's workflow, as an interface."""

    def generate_sketch(self, spec: AttnSpec) -> str:
        """Stage 1a: user requirement -> TL Sketch text."""
        ...

    def reason_parameters(self, sketch_text: str, spec: AttnSpec,
                          q_len: int, kv_len: int, target: TPUTarget,
                          blocks: BlockConfig | None,
                          num_splits: int | None = None) -> str:
        """Stage 1b: TL Sketch -> complete TL Code text."""
        ...


class DeterministicBackend:
    """Rule-driven implementation of both stages (the default)."""

    def generate_sketch(self, spec: AttnSpec) -> str:
        return generate_sketch_text(spec)

    def reason_parameters(self, sketch_text: str, spec: AttnSpec,
                          q_len: int, kv_len: int, target: TPUTarget,
                          blocks: BlockConfig | None = None,
                          num_splits: int | None = None) -> str:
        from .tl.printer import to_text

        sketch = parse(sketch_text, name=f"{spec.variant}_fwd_sketch")
        sketch.meta["stage"] = "sketch"
        prog = reason_parameters(sketch, spec, q_len=q_len, kv_len=kv_len,
                                 target=target, blocks=blocks,
                                 num_splits=num_splits)
        return to_text(prog)


class OneStageBackend(DeterministicBackend):
    """Ablation: emit TL code in a single pass, with the characteristic
    one-stage defects the paper documents (App. B)."""

    def __init__(self, failure: str = "reshape_omission"):
        if failure not in ("reshape_omission", "gemm_layout_error"):
            raise ValueError(failure)
        self.failure = failure

    def generate_tl_code(self, spec: AttnSpec, q_len: int, kv_len: int,
                         target: TPUTarget) -> str:
        from .tl.printer import to_text

        sketch = generate_sketch(spec)
        prog = reason_parameters(
            sketch, spec, q_len=q_len, kv_len=kv_len, target=target,
            omit_reshape=self.failure == "reshape_omission",
            gemm_layout_bug=self.failure == "gemm_layout_error",
        )
        return to_text(prog)
