"""Stage 1a — TL Sketch generation (paper §3.2.1).

A *sketch* captures only the semantic execution flow: which tensors move
between memory tiers and which computations fuse at which tier.  It has no
block sizes, no coordinates, no ``Allocate`` statements and — critically for
the paper's Appendix-B ablation — no ``Reshape`` between the two fused GEMMs.
Those are all added by the *Parameter Analysis and Reasoning* stage
(:mod:`repro.core.reason`).

The generator is deterministic (DESIGN.md assumption A1): the sketches below
are the canonical optimisation logic for each attention family, expressed in
exactly the TL statement forms of the paper's listings.  A real-LLM backend
can replace this module behind :class:`repro.core.llm.GeneratorBackend` —
the downstream validator and translator consume the same TL text either way.
"""

from __future__ import annotations

from .spec import AttnSpec
from .tl.ast import TLProgram
from .tl.parser import parse

# ---------------------------------------------------------------------------
# Canonical sketches.  Fusion is expressed the paper's way: consecutive
# Compute statements at the same tier with no intervening Copy.
# ---------------------------------------------------------------------------

_FLASH_FWD = """
// TL Sketch: fused flash attention forward ({variant})
Copy Q from global to shared
for i = 0:Tkv
    Copy K from global to shared
    Copy V from global to shared
    Compute GEMM Q_shared, K_shared.T and get S
    Compute Scale S, sm_scale and get S
{mask}    Compute Online_softmax S, m, l, acc and get P
    Compute GEMM P, V_shared and accumulate acc
end
Compute Divide acc, l and get acc
Compute Cast acc and get O
Copy O from register to global
"""

_MLA_FWD = """
// TL Sketch: fused MLA latent attention forward (absorbed QK^T / WV)
Copy Q from global to shared
for i = 0:Tkv
    Copy C from global to shared
    Compute GEMM Q_shared, C_shared.T and get S
    Compute Scale S, sm_scale and get S
{mask}    Compute Online_softmax S, m, l, acc and get P
    Compute Slice C_shared, 0, R and get Cn
    Compute GEMM P, Cn and accumulate acc
end
Compute Divide acc, l and get acc
Compute Cast acc and get O
Copy O from register to global
"""

_MASK_CAUSAL = "    Compute Mask_causal S, q, i\n"
_MASK_WINDOW = "    Compute Mask_window S, q, i, W\n"


class SketchError(ValueError):
    pass


def generate_sketch_text(spec: AttnSpec) -> str:
    """Emit the TL Sketch for ``spec`` as TL text (the LLM-exchange format)."""

    if spec.variant == "mla":
        template = _MLA_FWD
    else:
        template = _FLASH_FWD

    mask = ""
    if spec.causal:
        mask += _MASK_CAUSAL
    if spec.window is not None:
        mask += _MASK_WINDOW
    return template.format(variant=spec.variant, mask=mask).strip() + "\n"


def generate_sketch(spec: AttnSpec) -> TLProgram:
    name = f"{spec.variant}_{'decode' if spec.mode == 'decode' else 'fwd'}_sketch"
    prog = parse(generate_sketch_text(spec), name=name)
    prog.meta["spec"] = spec
    prog.meta["stage"] = "sketch"
    return prog
