"""Analytic block-size autotuner — the paper's "Parameter Analysis and
Reasoning" made into an explicit self-optimizing search.

The paper's LLM reasons block sizes from the GPU spec in one shot.  Here the
same decision is a deterministic search over MXU-aligned (BM, BN) candidates
scored by a three-term napkin model per (q-tile, kv-tile) step:

  compute  = 2*BM*BN*(Dqk+Dv) / peak_flops          (MXU work)
  memory   = BN*(Dqk+Dv)*bytes / hbm_bw             (KV tile DMA; Q amortised)
  overhead = fixed per-grid-step cost               (Mosaic loop/DMA setup)

The step time is max(compute, memory) + overhead; the score divides useful
FLOPs (padding-discounted) by that.  Candidates whose working set exceeds
the VMEM budget are rejected — exactly the constraint the validator enforces
post-hoc (E004).  Results are cached per (spec, shape, target).
"""

from __future__ import annotations

import dataclasses
import functools

from .reason import LANE, MAX_KV_SPLITS, BlockConfig, _vmem_bytes
from .spec import AttnSpec
from .target import TPUTarget, dtype_bytes, get_target

# fixed per-grid-step overhead (s): DMA descriptor setup + loop bookkeeping.
# Calibrated so that 128x128 tiles on v5e land near published flash kernels'
# sweet spot; only relative ordering matters for the search.
_STEP_OVERHEAD_S = 2.0e-6

# Split-KV scoring constants, in KV-token equivalents (only relative
# ordering matters).  Merging one extra partial (acc, m, l) tile in the
# LSE-combine stage costs about this much KV traffic:
_SPLIT_COMBINE_TOKENS = 8.0
# and each extra *wave* (when rows*splits overflows the target's parallel
# program slots, the scheduler serialises a second round of programs) pays
# a dispatch cost on top of its KV read:
_WAVE_OVERHEAD_TOKENS = 16.0


@dataclasses.dataclass(frozen=True)
class TuneResult:
    blocks: BlockConfig
    est_time_s: float
    efficiency: float          # useful-FLOPs / (peak * est_time)
    candidates_tried: int
    table: tuple = ()          # (bm, bn, est_time, eff) rows for reports


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def estimate_time(spec: AttnSpec, q_len: int, kv_len: int, bm: int, bn: int,
                  target: TPUTarget) -> float:
    """Total napkin time for one (batch, head) attention instance."""

    dqk, dv = spec.qk_dim, spec.v_dim
    in_b = dtype_bytes(spec.dtype)
    tq = _ceil_div(q_len, bm)
    tkv = _ceil_div(kv_len, bn)
    if spec.window is not None:
        # sliding window: only ~ceil(W/BN)+1 KV tiles are live per q tile
        per_q = min(tkv, _ceil_div(spec.window, bn) + 1)
        live_steps = tq * per_q
    elif spec.causal and spec.mode == "full" and q_len == kv_len:
        # causal block-skip: roughly half the (q, kv) tiles are live
        live_steps = sum(_ceil_div((qi * bm + bm), bn) for qi in range(tq))
        live_steps = min(live_steps, tq * tkv)
    else:
        live_steps = tq * tkv

    flops_per_step = 2.0 * bm * bn * (dqk + dv)
    bytes_per_step = bn * (dqk + dv) * in_b          # KV fetch dominates
    q_bytes = tq * bm * dqk * in_b                    # Q fetched once per row-tile

    compute = flops_per_step / (target.peak_bf16_tflops * 1e12)
    memory = bytes_per_step / (target.hbm_gbps * 1e9)
    t = live_steps * (max(compute, memory) + _STEP_OVERHEAD_S)
    t += q_bytes / (target.hbm_gbps * 1e9)
    return t


def useful_flops(spec: AttnSpec, q_len: int, kv_len: int) -> float:
    return spec.attention_flops(1, q_len, kv_len) / spec.num_q_heads


@functools.lru_cache(maxsize=512)
def _tune_cached(spec: AttnSpec, q_len: int, kv_len: int,
                 target_name: str) -> TuneResult:
    target = get_target(target_name)
    sub = 8
    bm_cands = [bm for bm in (8, 16, 32, 64, 128, 256, 512)
                if bm <= max(sub, _ceil_div(q_len, sub) * sub)]
    bn_cands = [bn for bn in (128, 256, 512, 1024)
                if bn <= max(128, _ceil_div(kv_len, 128) * 128)]

    best: tuple[float, BlockConfig] | None = None
    rows = []
    uf = useful_flops(spec, q_len, kv_len)
    for bm in bm_cands:
        for bn in bn_cands:
            if _vmem_bytes(spec, bm, bn) > target.vmem_budget:
                continue
            # padding waste discount
            pad = (_ceil_div(q_len, bm) * bm / q_len) * \
                  (_ceil_div(kv_len, bn) * bn / kv_len)
            t = estimate_time(spec, q_len, kv_len, bm, bn, target) * pad
            eff = uf / (target.peak_bf16_tflops * 1e12 * t)
            rows.append((bm, bn, t, eff))
            if best is None or t < best[0]:
                best = (t, BlockConfig(bm, bn))
    if best is None:
        raise ValueError(
            f"no (BM, BN) candidate fits VMEM for {spec} on {target.name}")
    t, blocks = best
    return TuneResult(blocks=blocks, est_time_s=t,
                      efficiency=uf / (target.peak_bf16_tflops * 1e12 * t),
                      candidates_tried=len(rows), table=tuple(rows))


def tune(spec: AttnSpec, q_len: int, kv_len: int,
         target: TPUTarget | str = "v5e") -> TuneResult:
    name = target if isinstance(target, str) else target.name
    return _tune_cached(spec, q_len, kv_len, name)


# ---------------------------------------------------------------------------
# split-KV work-partitioning search (Flash-Decoding / FA-2's parallelism axis)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SplitTune:
    """Scored split-KV decision for one decode/verify dispatch."""

    num_splits: int
    est_cost: float            # critical-path cost in KV-token equivalents
    candidates_tried: int
    table: tuple = ()          # (splits, waves, cost) rows for reports


@functools.lru_cache(maxsize=2048)
def _tune_splits_cached(rows: int, kv_len: int, unit: int,
                        target_name: str, shards: int = 1) -> SplitTune:
    """Score every legal split count and keep the cheapest critical path.

    The same napkin reasoning as the block search, one level up: a decode
    (or speculative-verify) grid exposes ``rows = bsz * heads`` parallel
    programs; splitting the KV axis ``s`` ways multiplies the program count
    by ``s`` but divides each program's sequential KV read by ``s``.  The
    critical path is then

      waves(s)   = ceil(rows * s / decode_parallelism)   (program rounds)
      cost(s)    = waves * (ceil(units/s) * unit + wave overhead)
                   + (s - 1) * combine cost              (extra LSE merges)

    measured in KV-token equivalents — only the ordering matters.  ``unit``
    is the indivisible split quantum (one page when paged, one lane tile
    dense), so candidates are clamped to whole units and to
    :data:`~repro.core.reason.MAX_KV_SPLITS`.  Ties break toward fewer
    splits (less partial-tile HBM).

    ``shards`` is the model-axis width of a sharded serving mesh: the
    head grid is divided across ``shards`` devices, so each device sees
    ``ceil(rows / shards)`` rows and needs proportionally *more* KV
    splitting to fill its ``decode_parallelism`` slots.  Scoring the
    per-shard rows keeps the decision device-local (every shard makes the
    same choice — the inputs are replicated scalars).
    """
    target = get_target(target_name)
    par = max(1, int(target.decode_parallelism))
    units = max(1, _ceil_div(max(1, int(kv_len)), max(1, int(unit))))
    rows = max(1, _ceil_div(max(1, int(rows)), max(1, int(shards))))

    best: tuple[float, int] | None = None
    table = []
    for s in range(1, min(units, MAX_KV_SPLITS) + 1):
        waves = _ceil_div(rows * s, par)
        per_split = _ceil_div(units, s) * unit
        cost = waves * (per_split + _WAVE_OVERHEAD_TOKENS) \
            + (s - 1) * _SPLIT_COMBINE_TOKENS
        table.append((s, waves, cost))
        if best is None or cost < best[0]:
            best = (cost, s)
    cost, s = best
    return SplitTune(num_splits=s, est_cost=cost,
                     candidates_tried=len(table), table=tuple(table))


def tune_splits(*, rows: int, kv_len: int, page_size=None,
                target: TPUTarget | str = "v5e",
                shards: int = 1) -> SplitTune:
    """Split-KV partition search for a decode/verify dispatch.

    ``reason.choose_num_splits`` delegates here — the split decision lives
    in the same scored-search framework as the (BM, BN) decision, keyed by
    the same :class:`~repro.core.target.TPUTarget` calibration
    (``decode_parallelism``).  ``shards`` (model-axis width of a serving
    mesh) scores waves against per-shard rows — see
    :func:`_tune_splits_cached`.
    """
    name = target if isinstance(target, str) else target.name
    unit = int(page_size) if page_size else LANE
    return _tune_splits_cached(int(rows), int(kv_len), unit, name,
                               int(shards))
