from .jnp_backend import translate_jnp  # noqa: F401
from .pallas_backend import translate_pallas  # noqa: F401
