"""TL -> pure-jnp translation (the oracle backend).

Interprets a reasoned TL program with plain ``jnp`` ops at block granularity:
``Copy`` statements become array slices, ``Compute`` statements call the
shared semantics table, the ``for`` loop runs in Python.  The result is an
executable *definition* of what the TL program means — the Pallas backend is
tested against it, and it in turn is tested against the closed-form
softmax-attention reference in ``kernels/ref.py`` (three-way agreement).

Operates on single-(batch, head) 2-D tensors; batching/head mapping is the
wrapper's job (``kernels/ops.py``).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..tl.ast import (
    Allocate,
    ComputeGEMM,
    ComputeOp,
    Copy,
    ForLoop,
    If,
    MemSpace,
    Reshape,
    TLProgram,
)
from ..reason import split_layout
from ..tl.validator import base_name
from . import semantics


class TranslateError(NotImplementedError):
    pass


def _pad_to(x, rows):
    if x.shape[0] == rows:
        return x
    pad = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def translate_jnp(prog: TLProgram, *, shard_axis: str | None = None):
    """Return ``fn(*global_inputs) -> output`` implementing ``prog``.

    Runtime-length programs (``meta['runtime_kv_len']`` — decode mode) take
    a leading ``kv_len`` argument, mirroring the Pallas backend's scalar
    operand: ``fn(kv_len, *global_inputs)``.  ``params['N']`` is only the
    bucket capacity; columns at or past ``kv_len`` are masked.

    Paged programs (``meta['paged']``) take ``fn(kv_len, block_table,
    *global_inputs)`` — the identical gather contract as the Pallas
    backend, so parity tests stay backend-agnostic.  The KV inputs are
    page pools flattened to 2-D — ``(P * PAGE_SIZE, D)`` — and
    ``block_table`` is this row's ``(N // PAGE_SIZE,)`` vector of physical
    page indices (a *concrete* sequence: the oracle runs the loop in
    Python).  Logical KV tile ``i`` is read from physical rows
    ``table[i*BN // PAGE_SIZE] * PAGE_SIZE + (i*BN) % PAGE_SIZE`` onward.

    Quantized-page programs (``meta['kv_quant']``) insert one ``(P,)`` f32
    per-page scale vector per int8 pool between the table and the inputs —
    ``fn(kv_len, block_table, k_scale, v_scale, q2d, k2d, v2d)`` (MLA:
    ``c_scale``) — and each gathered tile is dequantized
    (``int8 * scale``) before the score GEMM, identical to Pallas.

    Chunked-prefill programs (``meta['chunk_prefill']``) reuse the paged
    signature with the leading scalar reinterpreted as the *history*
    length: the M q rows sit at positions ``hist .. hist+M-1`` and the
    causal mask offset is the runtime scalar (mirroring the Pallas
    backend's runtime-shifted diagonal; no separate bounds mask).

    Split-KV programs (``params['NUM_SPLITS'] > 1``) run the KV loop once
    per split over that split's tile slice with *fresh* online-softmax
    state, then LSE-merge the partials (:func:`semantics.lse_merge`)
    before the epilogue — the identical split/merge the Pallas backend
    launches as a parallel grid dimension plus combine kernel, so parity
    tests exercise the same partition arithmetic on both backends.

    ``shard_axis`` makes the translation shard-aware for use inside
    ``shard_map``: each mesh rank runs the KV loop over its *local* KV
    slice (the program's ``N`` is the per-rank capacity; a rank whose
    runtime length is 0 contributes nothing), then the online-softmax
    state is LSE-merged across the named axis
    (:func:`semantics.lse_merge_axis`) before the epilogue — the
    sequence-parallel form of the split-KV combine.
    """

    p = dict(prog.params)
    bm, bn = int(p["BM"]), int(p["BN"])
    m_real, n_real = int(p["M"]), int(p["N"])
    tkv = int(p["Tkv"])
    runtime_kv = bool(prog.meta.get("runtime_kv_len") or p.get("KV_RUNTIME"))
    paged = bool(prog.meta.get("paged") or p.get("KV_PAGED"))
    chunked = bool(prog.meta.get("chunk_prefill") or p.get("KV_CHUNK"))
    page = int(p["PAGE_SIZE"]) if paged else None
    mpp = page // bn if paged else None    # KV tiles per page
    # quantized int8 pools: one f32 absmax scale per page, passed between
    # the block table and the regular inputs (same contract as Pallas)
    kv_quant = bool(prog.meta.get("kv_quant") or p.get("KV_QUANT"))
    quant_names = (("C",) if "C" in prog.inputs else ("K", "V")) \
        if kv_quant else ()
    # split-KV: the same fixed-point layout the Pallas backend derives
    ns, tps = split_layout(int(p.get("NUM_SPLITS", 1)), tkv, mpp or 1)
    n_pad = tkv * bn
    tq = -(-m_real // bm)
    m_pad = tq * bm
    allocs = prog.allocations()
    out_name = prog.outputs[0]
    out_dtype = {"bf16": jnp.bfloat16, "f32": jnp.float32,
                 "f16": jnp.float16,
                 "fp8": jnp.bfloat16}[allocs[out_name].dtype]

    def run_block(env: dict, q_idx: int, kv_limit=None,
                  table=None) -> jnp.ndarray:
        """Execute the TL body for one q-tile coordinate.

        ``kv_limit``: the runtime cache length for runtime-length programs
        (None for compile-time-length programs).  ``table``: the physical
        page index per logical page for paged programs (concrete ints).
        """

        state: dict = {}

        def reset_registers():
            # register allocations -> initial values (fresh online-softmax
            # state; split-KV resets these once per split partition)
            for a in allocs.values():
                if a.space is MemSpace.REGISTER and a.name != "S":
                    shape = tuple(prog.resolve(d) for d in a.shape)
                    if a.name == "m":
                        state[a.name] = jnp.full(shape, semantics.NEG_INF,
                                                 jnp.float32)
                    else:
                        state[a.name] = jnp.zeros(shape, jnp.float32)

        reset_registers()

        loop_env = {"q": q_idx}

        def coord_of(stmt: Copy) -> int:
            expr = next(iter(stmt.coords.values())) if stmt.coords else "q"
            return int(loop_env.get(expr, 0)) if not str(expr).isdigit() else int(expr)

        def q_positions():
            return (q_idx * bm + np.arange(bm)).reshape(bm, 1)

        def k_positions(i):
            return (i * bn + np.arange(bn)).reshape(1, bn)

        def exec_stmts(stmts):
            for s in stmts:
                if isinstance(s, Allocate):
                    continue
                if isinstance(s, Reshape):
                    # accumulator-layout -> operand-layout: on the oracle this
                    # is the dtype re-declaration before the second GEMM
                    state[base_name(s.name)] = state[base_name(s.name)]
                    continue
                if isinstance(s, ForLoop):
                    start = prog.resolve(s.start) if not isinstance(s.start, int) else s.start
                    end = prog.resolve(s.end) if not isinstance(s.end, int) else s.end
                    if ns > 1:
                        # split-KV: run the loop per split slice with fresh
                        # state, then LSE-merge the partials — mirroring
                        # the Pallas parallel split grid + combine kernel
                        parts = []
                        for si in range(ns):
                            reset_registers()
                            for it in range(start + si * tps,
                                            min(start + (si + 1) * tps, end)):
                                loop_env[s.var] = it
                                exec_stmts(s.body)
                            parts.append((state["acc"], state["m"],
                                          state["l"]))
                        state["acc"], state["m"], state["l"] = \
                            semantics.lse_merge(
                                jnp.stack([a for a, _, _ in parts]),
                                jnp.stack([m for _, m, _ in parts]),
                                jnp.stack([l for _, _, l in parts]))
                    else:
                        for it in range(start, end):
                            loop_env[s.var] = it
                            exec_stmts(s.body)
                    if shard_axis is not None:
                        # sequence-parallel ranks: merge the per-rank
                        # online-softmax state before the epilogue
                        state["acc"], state["m"], state["l"] = \
                            semantics.lse_merge_axis(
                                state["acc"], state["m"], state["l"],
                                shard_axis)
                    continue
                if isinstance(s, If):
                    raise TranslateError("If unsupported in jnp backend")
                if isinstance(s, Copy):
                    nm = base_name(s.name)
                    if s.src is MemSpace.GLOBAL:
                        i = coord_of(s)
                        rows = prog.resolve(s.shape[0])
                        if table is not None and allocs[nm].shape[0] == "N":
                            # paged gather: logical tile i -> physical rows
                            # (BN | PAGE_SIZE, so a tile never straddles)
                            pg = int(table[i // mpp])
                            start = pg * page + (i % mpp) * bn
                            tile = jnp.asarray(env[nm][start:start + rows])
                            if nm in env.get("__scales__", ()):
                                # int8 page dequant: the tile lives in one
                                # page, so one scalar scale covers it
                                tile = tile.astype(jnp.float32) \
                                    * env["__scales__"][nm][pg]
                            state[nm] = tile
                        else:
                            state[nm] = jnp.asarray(
                                env[nm][i * rows:(i + 1) * rows])
                    elif s.dst is MemSpace.GLOBAL:
                        state["__out__"] = state[nm]
                    continue
                if isinstance(s, ComputeGEMM):
                    a = state[base_name(s.a.name)].astype(jnp.float32)
                    b = state[base_name(s.b.name)].astype(jnp.float32)
                    if s.a.transposed:
                        a = a.T
                    if s.b.transposed:
                        b = b.T
                    r = jnp.dot(a, b, preferred_element_type=jnp.float32)
                    nm = base_name(s.out)
                    state[nm] = state[nm] + r if s.accumulate else r
                    continue
                if isinstance(s, ComputeOp):
                    exec_op(s)
                    continue
                raise TranslateError(f"unsupported TL statement {s!r}")

        def exec_op(s: ComputeOp):
            op = s.op
            i = int(loop_env.get("i", 0))
            if op == "scale":
                src = state[base_name(s.args[0])]
                state[base_name(s.out)] = semantics.scale(
                    src, float(p[s.args[1]]))
            elif op == "mask_causal":
                nm = base_name(s.args[0])
                # chunked prefill: runtime history length shifts the
                # diagonal (mirrors the Pallas backend exactly)
                off = kv_limit if chunked else int(p.get("QOFF", 0))
                state[nm] = semantics.mask_causal(
                    state[nm], q_positions(), k_positions(i), off)
            elif op == "mask_window":
                nm = base_name(s.args[0])
                state[nm] = semantics.mask_window(
                    state[nm], q_positions(), k_positions(i), int(p["W"]),
                    int(p.get("QOFF", 0)))
            elif op == "online_softmax":
                s_nm, m_nm, l_nm, acc_nm = [base_name(a) for a in s.args]
                scores = state[s_nm]
                if kv_limit is not None and not chunked:
                    # runtime cache length (chunked prefill's scalar is the
                    # history length — the shifted causal mask bounds it).
                    # A sequence-parallel rank may hold a local length past
                    # its own capacity (the global remainder); clamp so the
                    # zero-padded columns beyond N stay dead either way.
                    scores = semantics.mask_bounds(
                        scores, k_positions(i),
                        jnp.minimum(kv_limit, n_real))
                elif kv_limit is None and n_pad != n_real:  # padded KV cols
                    scores = semantics.mask_bounds(
                        scores, k_positions(i), n_real)
                pmat, state[m_nm], state[l_nm], state[acc_nm] = \
                    semantics.online_softmax(
                        scores, state[m_nm], state[l_nm], state[acc_nm])
                state[base_name(s.out)] = pmat
            elif op == "softmax":
                nm = base_name(s.args[0])
                state[nm] = semantics.softmax(state[nm])
            elif op == "slice":
                src = state[base_name(s.args[0])]
                lo, hi = prog.resolve(s.args[1]), prog.resolve(s.args[2])
                state[base_name(s.out)] = src[:, lo:hi]
            elif op == "divide":
                acc_nm, l_nm = base_name(s.args[0]), base_name(s.args[1])
                state[base_name(s.out)] = semantics.divide(
                    state[acc_nm], state[l_nm])
            elif op == "cast":
                state[base_name(s.out)] = state[base_name(s.args[0])].astype(out_dtype)
            else:
                raise TranslateError(f"unsupported TL op {op!r}")

        exec_stmts(prog.body)
        return state["__out__"]

    input_names = tuple(prog.inputs)

    def fn(*arrays):
        kv_limit = table = None
        scales = {}
        if paged:
            kv_len, table, *arrays = arrays
            if kv_quant:
                svals, arrays = arrays[:len(quant_names)], \
                    arrays[len(quant_names):]
                scales = {nm: jnp.asarray(s, jnp.float32).reshape(-1)
                          for nm, s in zip(quant_names, svals)}
            table = np.asarray(table).reshape(-1)
            if table.shape[0] * mpp != tkv:
                raise ValueError(
                    f"block table covers {table.shape[0]} pages; the "
                    f"program capacity N={n_real} needs {tkv // mpp}")
            try:
                kv_limit = int(kv_len)
            except TypeError:
                kv_limit = kv_len
        elif runtime_kv:
            kv_len, *arrays = arrays
            try:
                kv_limit = int(kv_len)
            except TypeError:  # traced scalar: fine, only used in jnp.where
                kv_limit = kv_len
        if len(arrays) != len(input_names):
            raise ValueError(f"expected inputs {input_names}"
                             + (" with a leading kv_len" if runtime_kv else ""))
        env = {"__scales__": scales} if scales else {}
        for nm, arr in zip(input_names, arrays):
            if allocs[nm].shape[0] == "M":
                env[nm] = _pad_to(arr, m_pad)
            elif paged:
                # page pool, flattened (P * PAGE_SIZE, D): rows are gathered
                # through the table, never sliced positionally — no padding
                env[nm] = jnp.asarray(arr)
            else:
                env[nm] = _pad_to(arr, n_pad)
        blocks = [run_block(env, qi, kv_limit, table) for qi in range(tq)]
        out = jnp.concatenate(blocks, axis=0)[:m_real]
        return out

    fn.input_names = input_names
    fn.program = prog
    fn.runtime_kv_len = runtime_kv
    fn.paged = paged
    fn.page_size = page
    fn.chunk_prefill = chunked
    fn.num_splits = ns
    fn.kv_quant = kv_quant
    return fn
