"""TL -> Pallas translation (the TPU backend; paper §3.3 re-grounded).

The paper translates each TL statement into CuTe: ``Allocate``/``Copy``
become tensor definitions + ``cute::copy`` over (global, shared, register),
``Compute GEMM`` becomes Tensor-Core ``mma`` atoms, and ``Reshape`` converts
an mma_C accumulator fragment into an mma_A operand fragment.

On TPU the same statements land on different hardware mechanisms
(DESIGN.md §2 table):

=====================  ====================================================
TL statement           Pallas/Mosaic realisation
=====================  ====================================================
``Allocate .. global``   kernel operand in HBM, tiled by a ``BlockSpec``
``Copy g->s``            the ``BlockSpec`` index map: Mosaic's pipelined
                         HBM->VMEM DMA *is* the copy (double-buffered)
``Allocate .. register`` VMEM scratch (``pltpu.VMEM``) carried across the
                         innermost (``arbitrary``) grid dimension
``Compute GEMM``         ``jnp.dot(..., preferred_element_type=f32)`` -> MXU
``Reshape mma_C->mma_A`` cast of the f32 softmax tile to the input dtype so
                         the second GEMM's A-operand feeds the MXU at its
                         native width (the layout re-declaration)
``for i = 0:Tkv``        innermost grid dimension (sequential/"arbitrary")
``Copy r->g (epilogue)`` output ref store predicated on the last grid step
=====================  ====================================================

Runtime operand classes (decode mode) extend the table:

=====================  ====================================================
runtime cache length     SMEM scalar operand (scalar-prefetch tier); the
                         kernel masks score columns and skips dead KV
                         blocks against it
block table (paged)      SMEM int vector per batch row, read by the KV
                         ``BlockSpec`` *index maps* — the HBM->VMEM DMA
                         itself is redirected to the physical page, so the
                         gather costs nothing over the dense copy
``NUM_SPLITS`` > 1       the KV loop is partitioned into a *parallel* grid
                         dimension (Flash-Decoding): each split program
                         runs the online softmax over its KV slice and
                         writes partial ``(acc, m, l)`` tiles; a small
                         combine kernel LSE-merges the partials and runs
                         the TL epilogue (divide/cast/store).  With one
                         split the epilogue stays fused in the main grid.
=====================  ====================================================

The translator is a *staging interpreter*: it walks the TL AST once at trace
time and emits the corresponding JAX ops inside the generated kernel body.
It supports the statement family the sketch generator produces (fused
two-GEMM online-softmax programs) and raises :class:`TranslateError`
otherwise — mirroring the paper's per-statement translation contract.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..tl.ast import (
    Allocate,
    ComputeGEMM,
    ComputeOp,
    Copy,
    ForLoop,
    MemSpace,
    Reshape,
    TLProgram,
)
from ..reason import split_layout
from ..tl.validator import base_name
from . import semantics
from .jnp_backend import TranslateError

# fp8 kernels execute at bf16 numerics in interpret mode (DESIGN A4);
# on fp8-capable MXUs the translator would emit float8_e4m3fn here.
_JDTYPE = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f16": jnp.float16,
           "fp8": jnp.bfloat16, "int8": jnp.int8}


def _compiler_params(dimension_semantics):
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    if cls is None:  # pragma: no cover - version drift guard
        return None
    try:
        return cls(dimension_semantics=dimension_semantics)
    except TypeError:  # pragma: no cover
        return None


@dataclasses.dataclass(frozen=True)
class _Structure:
    """The TL program split at its single KV loop."""

    prologue: tuple
    loop: ForLoop
    epilogue: tuple


def _split(prog: TLProgram) -> _Structure:
    loops = [s for s in prog.body if isinstance(s, ForLoop)]
    if len(loops) != 1:
        raise TranslateError(
            f"pallas backend expects exactly one top-level KV loop, found "
            f"{len(loops)} in {prog.name!r}")
    i = prog.body.index(loops[0])
    return _Structure(tuple(prog.body[:i]), loops[0], tuple(prog.body[i + 1:]))


def translate_pallas(
    prog: TLProgram,
    *,
    interpret: bool = True,
    causal_block_skip: bool = True,
    debug: bool = False,
    shard_axis: str | None = None,
):
    """Compile ``prog`` into a batched attention callable.

    Returns ``fn(q, k, v) -> o`` with shapes
    ``q: (B, Hq, M, Dqk)  k: (B, Hkv, Npad, Dqk)  v: (B, Hkv, Npad, Dv)``
    or, for MLA programs (single latent operand ``C``),
    ``fn(q, c) -> o`` with ``c: (B, Npad, Dqk)``.

    ``M`` must be a multiple of BM and ``Npad`` a multiple of BN; the real
    KV length is ``prog.params['N']`` and padded columns are masked inside
    the kernel.  (The ``ops.py`` wrappers do the padding.)

    Runtime-length programs (``meta['runtime_kv_len']`` — decode mode) take
    a *leading* ``kv_len`` operand instead: ``fn(kv_len, q, *kv)``.
    ``prog.params['N']`` is then only the compiled bucket capacity;
    ``kv_len`` — a python int, a scalar, or a per-batch-row ``(B,)``
    vector — is staged into SMEM (the TPU scalar-prefetch tier) and the
    kernel masks score columns and skips dead KV blocks against it at run
    time.  One compiled kernel serves every cache length ≤ capacity.

    Paged programs (``meta['paged']``) additionally take a *block table*:
    ``fn(kv_len, block_tables, q, k_pool, v_pool)`` (or ``(..., c_pool)``
    for MLA).  The KV operands are page *pools* — ``k/v: (P, Hkv,
    PAGE_SIZE, D)``, ``c: (P, PAGE_SIZE, Dqk)`` — shared by every request,
    and ``block_tables: (B, N // PAGE_SIZE) int32`` maps each batch row's
    logical page ``j`` to a physical pool page.  Both runtime operands ride
    the scalar-prefetch tier; the KV ``BlockSpec`` index maps read the
    table, so Mosaic's pipelined DMA gathers pages directly.  Rows whose
    table is shorter than ``N // PAGE_SIZE`` pages must pad with any valid
    page index (the engine uses a reserved dump page): the gather still
    issues the DMA, the runtime length mask discards the values.

    Quantized-page programs (``meta['kv_quant']`` — int8 pools) extend the
    paged signature with one per-page f32 scale vector per pool, between
    the block table and the regular operands:
    ``fn(kv_len, block_tables, k_scale, v_scale, q, k_pool, v_pool)`` (MLA:
    ``fn(kv_len, block_tables, c_scale, q, c_pool)``), each scale shaped
    ``(P,)``.  Scales ride the scalar-prefetch tier; the kernel multiplies
    each staged KV tile by its page's scale (one scalar per tile — BN
    divides PAGE_SIZE, so a tile never spans two scales) before QK^T.

    Split-KV programs (``params['NUM_SPLITS'] > 1`` — decode mode) keep
    the same call signature but change the launch: the KV tiles are
    partitioned into ``NUM_SPLITS`` page-aligned slices riding a
    *parallel* grid dimension, each program producing partial
    ``(acc, m, l)`` online-softmax state, and a second small kernel
    LSE-merges the partials (:func:`semantics.lse_merge`) before running
    the TL epilogue.  Per-row runtime lengths compose: a row whose cache
    ends before a split's slice leaves that split's state empty
    (``m = -inf, l = 0``) and the merge ignores it.

    Chunked-prefill programs (``meta['chunk_prefill']`` — paged) reuse the
    paged signature, but the leading scalar is the per-row *history*
    length: the M q rows are one prompt chunk sitting at runtime positions
    ``hist .. hist+M-1`` of the paged cache (whose pages, including the
    chunk's own tokens, must be written before the call).  The causal mask
    becomes ``k_pos <= hist + q_pos`` — the runtime scalar shifts the
    diagonal, so it doubles as the bounds mask for real rows — and the
    dead-block skip keeps KV tiles past ``hist + (qi+1)*BM - 1`` off the
    MXU.  Rows past the chunk's true length are garbage (finite, never
    NaN) and the caller discards them.

    ``shard_axis`` makes the launch shard-aware for use inside
    ``shard_map``: every rank of the named mesh axis holds a *local* KV
    slice (its head shard's pages, or a sequence shard), the main kernel
    is forced into the partial-state (split) launch even at
    ``NUM_SPLITS == 1``, the per-rank partial ``(acc, m, l)`` tiles are
    ``all_gather``ed along the axis (a collective between the two
    ``pallas_call``s, never inside a kernel), and the LSE-combine kernel
    merges ``ranks * NUM_SPLITS`` partials — the distributed form of the
    Flash-Decoding combine.
    """

    p = dict(prog.params)
    bm, bn = int(p["BM"]), int(p["BN"])
    n_real = int(p["N"])
    tkv = int(p["Tkv"])
    runtime_kv = bool(prog.meta.get("runtime_kv_len")
                      or p.get("KV_RUNTIME"))
    paged = bool(prog.meta.get("paged") or p.get("KV_PAGED"))
    # chunked prefill: the runtime scalar is the *history* length and the
    # causal diagonal is shifted by it at run time (see the docstring)
    chunked = bool(prog.meta.get("chunk_prefill") or p.get("KV_CHUNK"))
    page = int(p["PAGE_SIZE"]) if paged else None
    mpp = page // bn if paged else None     # KV tiles per page (BN | PAGE_SIZE)
    # Quantized KV pages: the pools hold int8, one f32 absmax scale per
    # physical page rides the scalar-prefetch tier after the block table,
    # and the Copy g->s materialisation dequantizes the tile before QK^T.
    kv_quant = bool(prog.meta.get("kv_quant") or p.get("KV_QUANT"))
    mla = "C" in prog.inputs
    quant_names = (("C",) if mla else ("K", "V")) if kv_quant else ()
    # split-KV decode (Flash-Decoding): NUM_SPLITS parallel KV partitions,
    # re-derived through the same fixed-point layout the reasoning stage
    # used (whole tiles; page-aligned in paged layouts)
    ns, tps = split_layout(int(p.get("NUM_SPLITS", 1)), tkv, mpp or 1)
    # a shard axis forces the partial-state launch even at one split: the
    # rank-local state must survive the kernel so it can be gathered
    split = ns > 1 or shard_axis is not None
    allocs = prog.allocations()
    structure = _split(prog)
    out_name = prog.outputs[0]
    out_dtype = _JDTYPE[allocs[out_name].dtype]
    in_dtype = _JDTYPE[allocs[prog.inputs[0]].dtype]
    dv = prog.resolve(allocs[out_name].shape[1])
    lane = int(p.get("LANE", 128))
    q_off = int(p.get("QOFF", 0))
    causal = any(
        isinstance(s, ComputeOp) and s.op == "mask_causal" for s in prog.walk())

    # ---- the generated kernel body -----------------------------------------
    def make_kernel(hq: int):
        """``hq`` (q-heads per batch row) maps grid dim 0 back to the batch
        row for the per-row scalar operands; only the paged path needs it."""

        def kernel(*refs):
            kv_len = None
            scale_refs = {}
            brow = None
            if paged:
                # scalar-prefetch tier: full (B,) lens + (B, Tp) table in
                # SMEM; the table is consumed by the BlockSpec index maps.
                # Quantized pools add one (P,) f32 scale vector per pool,
                # gathered per page through the same table.
                lens_ref, _table_ref, *refs = refs
                if kv_quant:
                    srefs, refs = refs[:len(quant_names)], \
                        refs[len(quant_names):]
                    scale_refs = dict(zip(quant_names, srefs))
                brow = pl.program_id(0) // hq
                kv_len = lens_ref[brow]
            elif runtime_kv:
                # the (1, 1) SMEM tile the BlockSpec indexed to this row
                kv_ref, *refs = refs
                kv_len = kv_ref[0, 0]
            ni = len(prog.inputs)
            in_refs = refs[:ni]
            if split:
                # partial-state outputs; the LSE combine normalises later
                o_ref = None
                oa_ref, om_ref, ol_ref = refs[ni:ni + 3]
                acc_ref, m_ref, l_ref = refs[ni + 3:]
            else:
                o_ref = refs[ni]
                acc_ref, m_ref, l_ref = refs[ni + 1:]
            qi = pl.program_id(1)
            if split:
                si, kj = pl.program_id(2), pl.program_id(3)
                ki = si * tps + kj       # global KV tile of this step
            else:
                ki = pl.program_id(2)
                kj = ki                  # step within the (single) split

            @pl.when(kj == 0)
            def _init():
                acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
                m_ref[...] = jnp.full(m_ref.shape, semantics.NEG_INF,
                                      m_ref.dtype)
                l_ref[...] = jnp.zeros(l_ref.shape, l_ref.dtype)

            env: dict = {}
            for nm, ref in zip(prog.inputs, in_refs):
                env[nm + "__ref"] = ref

            def q_pos():
                rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
                return qi * bm + rows

            def k_pos():
                # logical KV positions: the paged gather restores logical
                # order inside the tile, so ki * bn is correct there too
                cols = jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
                return ki * bn + cols

            def run_stmt(s, phase: str):
                if isinstance(s, Allocate):
                    return
                if isinstance(s, Copy):
                    nm = base_name(s.name)
                    if s.src is MemSpace.GLOBAL:
                        # Copy g->s: the BlockSpec already staged the tile
                        # into VMEM; materialise it into the trace env.
                        ref = env[nm + "__ref"]
                        tile = ref[...].reshape(ref.shape[-2:])
                        if nm in scale_refs:
                            # int8 page dequant: every row of this KV tile
                            # lives in one physical page (BN | PAGE_SIZE),
                            # so one scalar scale covers the whole tile
                            s_pg = scale_refs[nm][_table_ref[brow,
                                                             ki // mpp]]
                            tile = tile.astype(jnp.float32) * s_pg
                        env[nm] = tile
                    elif s.dst is MemSpace.GLOBAL:
                        val = env[nm].astype(out_dtype)
                        o_ref[...] = val.reshape(o_ref.shape)
                    return
                if isinstance(s, Reshape):
                    # mma_C -> mma_A: f32 accumulator tile re-declared as an
                    # input-dtype MXU operand tile.
                    env[base_name(s.name)] = \
                        env[base_name(s.name)].astype(in_dtype)
                    return
                if isinstance(s, ComputeGEMM):
                    a = env[base_name(s.a.name)]
                    b = env[base_name(s.b.name)]
                    if s.a.transposed:
                        a = a.T
                    if s.b.transposed:
                        b = b.T
                    r = jnp.dot(a, b, preferred_element_type=jnp.float32)
                    nm = base_name(s.out)
                    if s.accumulate:
                        acc_ref[...] += r
                    else:
                        env[nm] = r
                    return
                if isinstance(s, ComputeOp):
                    run_op(s)
                    return
                raise TranslateError(f"unsupported statement {s!r} in {phase}")

            def run_op(s: ComputeOp):
                op = s.op
                if op == "scale":
                    env[base_name(s.out)] = semantics.scale(
                        env[base_name(s.args[0])], float(p[s.args[1]]))
                elif op == "mask_causal":
                    nm = base_name(s.args[0])
                    # chunked prefill: the causal offset is the runtime
                    # history length (chunk row i sits at position hist+i),
                    # not the static QOFF
                    env[nm] = semantics.mask_causal(
                        env[nm], q_pos(), k_pos(),
                        kv_len if chunked else q_off)
                elif op == "mask_window":
                    nm = base_name(s.args[0])
                    env[nm] = semantics.mask_window(
                        env[nm], q_pos(), k_pos(), int(p["W"]), q_off)
                elif op == "online_softmax":
                    scores = env[base_name(s.args[0])]
                    if runtime_kv and not chunked:
                        # runtime bounds mask: the true cache length (≤ the
                        # compiled capacity, which the padding honours).
                        # Chunked prefill needs none: its scalar is the
                        # history length and the shifted causal mask
                        # already bounds every real row at hist + row.
                        scores = semantics.mask_bounds(scores, k_pos(),
                                                       kv_len)
                    elif not runtime_kv and tkv * bn != n_real:
                        scores = semantics.mask_bounds(scores, k_pos(),
                                                       n_real)
                    pmat, m_new, l_new, acc_new = semantics.online_softmax(
                        scores, m_ref[...], l_ref[...], acc_ref[...])
                    m_ref[...] = m_new
                    l_ref[...] = l_new
                    acc_ref[...] = acc_new
                    env[base_name(s.out)] = pmat
                elif op == "slice":
                    src = env[base_name(s.args[0])]
                    lo, hi = prog.resolve(s.args[1]), prog.resolve(s.args[2])
                    env[base_name(s.out)] = src[:, lo:hi]
                elif op == "divide":
                    env[base_name(s.out)] = semantics.divide(
                        acc_ref[...], l_ref[...])
                elif op == "cast":
                    env[base_name(s.out)] = \
                        env[base_name(s.args[0])].astype(out_dtype)
                else:
                    raise TranslateError(f"unsupported TL op {op!r}")

            for s in structure.prologue:
                run_stmt(s, "prologue")

            # KV-loop body.  With a causal mask, tiles strictly above the
            # diagonal contribute nothing; with a sliding window, neither do
            # tiles entirely below it — predicate the whole body away
            # (compute skip; the DMA still ran, see EXPERIMENTS.md §Perf).
            window = p.get("W")
            live = None
            if causal and causal_block_skip and not chunked:
                # static diagonal skip; chunked prefill's diagonal is
                # runtime-shifted, handled below
                live = ki * bn <= qi * bm + (bm - 1) + q_off
            if window is not None and causal_block_skip:
                lo = (ki + 1) * bn - 1 > qi * bm + q_off - int(window)
                live = lo if live is None else (live & lo)
            if runtime_kv:
                # KV blocks entirely past the runtime length contribute
                # nothing: skip them so a short cache in a large bucket pays
                # for the blocks it uses, not the bucket capacity.  For
                # chunked prefill the frontier is the runtime-shifted
                # causal diagonal of the q tile's last row.
                if chunked:
                    rt = ki * bn <= kv_len + qi * bm + (bm - 1)
                else:
                    rt = ki * bn < kv_len
                live = rt if live is None else (live & rt)
            if split and ns * tps != tkv:
                # uneven last split: its tail programs address a clamped
                # (valid) tile via the index maps but must not compute
                tail = ki < tkv
                live = tail if live is None else (live & tail)
            if live is not None:
                @pl.when(live)
                def _body():
                    for s in structure.loop.body:
                        run_stmt(s, "loop")
            else:
                for s in structure.loop.body:
                    run_stmt(s, "loop")

            if split:
                # this split's partial online-softmax state, written once
                # on its last step; divide/cast move to the combine kernel
                @pl.when(kj == tps - 1)
                def _write_partials():
                    oa_ref[...] = acc_ref[...].reshape(oa_ref.shape)
                    om_ref[...] = m_ref[...].reshape(om_ref.shape)
                    ol_ref[...] = l_ref[...].reshape(ol_ref.shape)
            else:
                @pl.when(kj == tkv - 1)
                def _epilogue():
                    for s in structure.epilogue:
                        run_stmt(s, "epilogue")

        return kernel

    # ---- the LSE-combine stage (split-KV decode only) ----------------------
    def make_combine_kernel():
        """Merge the ``NUM_SPLITS`` partial (acc, m, l) tiles of one
        (batch-head, q-tile) coordinate and run the TL epilogue
        (``Divide``/``Cast``/``Copy O``) on the merged state — the same
        statements the fused epilogue executes in the one-split launch."""

        def kernel(a_ref, mm_ref, ll_ref, o_ref):
            acc, m_c, l_c = semantics.lse_merge(
                a_ref[...].reshape(-1, *a_ref.shape[-2:]),
                mm_ref[...].reshape(-1, *mm_ref.shape[-2:]),
                ll_ref[...].reshape(-1, *ll_ref.shape[-2:]))
            env = {"acc": acc, "m": m_c, "l": l_c}
            for s in structure.epilogue:
                if isinstance(s, (Allocate, Reshape)):
                    continue
                if isinstance(s, ComputeOp) and s.op == "divide":
                    env[base_name(s.out)] = semantics.divide(
                        env[base_name(s.args[0])],
                        env[base_name(s.args[1])])
                elif isinstance(s, ComputeOp) and s.op == "cast":
                    env[base_name(s.out)] = \
                        env[base_name(s.args[0])].astype(out_dtype)
                elif isinstance(s, Copy) and s.dst is MemSpace.GLOBAL:
                    val = env[base_name(s.name)].astype(out_dtype)
                    o_ref[...] = val.reshape(o_ref.shape)
                else:
                    raise TranslateError(
                        f"split decode cannot lower epilogue {s!r}")

        return kernel

    # ---- BlockSpecs from the TL Copy statements ------------------------------
    def build(*operands):
        kv_len_arg = table_arg = None
        scale_args = ()
        if paged:
            kv_len_arg, table_arg, *operands = operands
            if kv_quant:
                scale_args = tuple(operands[:len(quant_names)])
                operands = operands[len(quant_names):]
        elif runtime_kv:
            kv_len_arg, *operands = operands
        q, *kv = operands
        bsz, hq, m, dqk = q.shape
        if m % bm:
            raise ValueError(f"q rows {m} not a multiple of BM={bm}")
        tq = m // bm

        # Split-KV launches replace the KV grid id ``ki`` with a
        # (parallel split, step) pair; ``mk`` re-hosts the 3-d index maps
        # below onto the 4-d grid so the tile arithmetic is written once.
        def _kt(si, kj):
            t = si * tps + kj
            if ns * tps != tkv:
                # dead tail programs of an uneven last split: clamp to a
                # valid tile (their compute is predicated off in-kernel)
                t = jnp.minimum(t, tkv - 1)
            return t

        if split:
            def mk(f):
                return lambda bh, qi, si, kj, *pf: \
                    f(bh, qi, _kt(si, kj), *pf)
        else:
            def mk(f):
                return f

        if paged:
            table = jnp.asarray(table_arg, jnp.int32)
            if table.ndim != 2 or table.shape[0] != bsz:
                raise ValueError(f"block table must be (B={bsz}, Tp), got "
                                 f"{table.shape}")
            if table.shape[1] * mpp != tkv:
                raise ValueError(
                    f"block table covers {table.shape[1]} pages = "
                    f"{table.shape[1] * page} tokens; the compiled capacity "
                    f"is N={n_real} ({tkv} KV tiles)")

            # paged index maps receive the scalar-prefetch refs; logical KV
            # tile ki lives in page table[b, ki // mpp] at tile ki % mpp
            def kv_page(table_ref, b, ki):
                return table_ref[b, ki // mpp]

        if mla:
            (c,) = kv
            hkv = 1
            if paged:
                if c.shape[-2] != page:
                    raise ValueError(f"latent pool page axis {c.shape[-2]} "
                                     f"!= PAGE_SIZE={page}")
                in_specs = [
                    pl.BlockSpec((1, 1, bm, dqk),
                                 mk(lambda bh, qi, ki, lens, tbl, *sc:
                                    (bh // hq, bh % hq, qi, 0))),
                    pl.BlockSpec((1, bn, dqk),
                                 mk(lambda bh, qi, ki, lens, tbl, *sc:
                                    (kv_page(tbl, bh // hq, ki),
                                     ki % mpp, 0))),
                ]
            else:
                if c.shape[1] % bn:
                    raise ValueError(
                        f"kv rows {c.shape[1]} not a multiple of BN={bn}")
                in_specs = [
                    pl.BlockSpec((1, 1, bm, dqk),
                                 mk(lambda bh, qi, ki:
                                    (bh // hq, bh % hq, qi, 0))),
                    pl.BlockSpec((1, bn, dqk),
                                 mk(lambda bh, qi, ki: (bh // hq, ki, 0))),
                ]
            args = (q, c)
        else:
            k, v = kv
            if paged:
                hkv = k.shape[1]
                qpk = hq // hkv
                if k.shape[-2] != page:
                    raise ValueError(f"KV pool page axis {k.shape[-2]} != "
                                     f"PAGE_SIZE={page}")
                in_specs = [
                    pl.BlockSpec((1, 1, bm, dqk),
                                 mk(lambda bh, qi, ki, lens, tbl, *sc:
                                    (bh // hq, bh % hq, qi, 0))),
                    pl.BlockSpec((1, 1, bn, dqk),
                                 mk(lambda bh, qi, ki, lens, tbl, *sc:
                                    (kv_page(tbl, bh // hq, ki),
                                     (bh % hq) // qpk, ki % mpp, 0))),
                    pl.BlockSpec((1, 1, bn, v.shape[-1]),
                                 mk(lambda bh, qi, ki, lens, tbl, *sc:
                                    (kv_page(tbl, bh // hq, ki),
                                     (bh % hq) // qpk, ki % mpp, 0))),
                ]
            else:
                if k.shape[2] % bn:
                    raise ValueError(
                        f"kv rows {k.shape[2]} not a multiple of BN={bn}")
                hkv = k.shape[1]
                qpk = hq // hkv
                in_specs = [
                    pl.BlockSpec((1, 1, bm, dqk),
                                 mk(lambda bh, qi, ki:
                                    (bh // hq, bh % hq, qi, 0))),
                    pl.BlockSpec((1, 1, bn, dqk),
                                 mk(lambda bh, qi, ki:
                                    (bh // hq, (bh % hq) // qpk, ki, 0))),
                    pl.BlockSpec((1, 1, bn, v.shape[-1]),
                                 mk(lambda bh, qi, ki:
                                    (bh // hq, (bh % hq) // qpk, ki, 0))),
                ]
            args = (q, k, v)

        grid = (bsz * hq, tq, ns, tps) if split else (bsz * hq, tq, tkv)
        scratch = [
            pltpu.VMEM((bm, dv), jnp.float32),
            pltpu.VMEM((bm, lane), jnp.float32),
            pltpu.VMEM((bm, lane), jnp.float32),
        ]
        kwargs = {}
        sem = ("parallel", "parallel", "parallel", "arbitrary") if split \
            else ("parallel", "parallel", "arbitrary")
        cp = _compiler_params(sem)
        if cp is not None and not interpret:
            kwargs["compiler_params"] = cp

        if split:
            # each split program writes its partial online-softmax state;
            # the LSE combine below reduces over the split axis
            out_shape = [
                jax.ShapeDtypeStruct((bsz, hq, ns, m, dv), jnp.float32),
                jax.ShapeDtypeStruct((bsz, hq, ns, m, lane), jnp.float32),
                jax.ShapeDtypeStruct((bsz, hq, ns, m, lane), jnp.float32),
            ]

            def psplit(bh, qi, si, kj, *pf):
                return (bh // hq, bh % hq, si, qi, 0)

            out_specs = [
                pl.BlockSpec((1, 1, 1, bm, dv), psplit),
                pl.BlockSpec((1, 1, 1, bm, lane), psplit),
                pl.BlockSpec((1, 1, 1, bm, lane), psplit),
            ]
        else:
            out_shape = jax.ShapeDtypeStruct((bsz, hq, m, dv), out_dtype)
            out_specs = pl.BlockSpec(
                (1, 1, bm, dv),
                mk(lambda bh, qi, ki, *pf: (bh // hq, bh % hq, qi, 0)))

        def combine(partials):
            """LSE-merge the per-split partials — the 'separate small
            kernel' realisation of the TL epilogue (one grid program per
            (batch-head, q-tile); the split axis is reduced in VMEM)."""
            if shard_axis is not None:
                # the collective lives between the two pallas_calls: stack
                # every rank's partial state along the split axis, so the
                # combine below merges ranks * NUM_SPLITS partials
                partials = tuple(
                    jax.lax.all_gather(x, shard_axis, axis=2, tiled=True)
                    for x in partials)
            nsp = int(partials[0].shape[2])
            ckw = {}
            ccp = _compiler_params(("parallel", "parallel"))
            if ccp is not None and not interpret:
                ckw["compiler_params"] = ccp
            cmap = lambda bh, qi: (bh // hq, bh % hq, 0, qi, 0)
            call = pl.pallas_call(
                make_combine_kernel(),
                grid=(bsz * hq, tq),
                in_specs=[
                    pl.BlockSpec((1, 1, nsp, bm, dv), cmap),
                    pl.BlockSpec((1, 1, nsp, bm, lane), cmap),
                    pl.BlockSpec((1, 1, nsp, bm, lane), cmap),
                ],
                out_specs=pl.BlockSpec(
                    (1, 1, bm, dv),
                    lambda bh, qi: (bh // hq, bh % hq, qi, 0)),
                out_shape=jax.ShapeDtypeStruct((bsz, hq, m, dv), out_dtype),
                interpret=interpret,
                debug=debug,
                **ckw,
            )
            return call(*partials)

        if paged:
            lens = jnp.asarray(kv_len_arg, jnp.int32).reshape(-1)
            lens = jnp.broadcast_to(lens, (bsz,))
            scales = ()
            if kv_quant:
                npool = args[1].shape[0]
                scales = tuple(jnp.asarray(s, jnp.float32).reshape(-1)
                               for s in scale_args)
                for s in scales:
                    if s.shape[0] != npool:
                        raise ValueError(
                            f"page scale vector has {s.shape[0]} rows; the "
                            f"pool has {npool} pages")
            grid_spec = pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2 + len(scales),
                grid=grid,
                in_specs=in_specs,
                out_specs=out_specs,
                scratch_shapes=scratch,
            )
            call = pl.pallas_call(
                make_kernel(hq),
                grid_spec=grid_spec,
                out_shape=out_shape,
                interpret=interpret,
                debug=debug,
                **kwargs,
            )
            out = call(lens, table, *scales, *args)
            return combine(out) if split else out

        if runtime_kv:
            # scalar operand: (B, 1) int32 in SMEM, one row per batch —
            # per-request cache lengths in a heterogeneous decode batch
            lens = jnp.asarray(kv_len_arg, jnp.int32).reshape(-1)
            lens = jnp.broadcast_to(lens, (bsz,)).reshape(bsz, 1)
            in_specs.insert(0, pl.BlockSpec(
                (1, 1), mk(lambda bh, qi, ki: (bh // hq, 0)),
                memory_space=pltpu.SMEM))
            args = (lens,) + args

        call = pl.pallas_call(
            make_kernel(hq),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
            debug=debug,
            **kwargs,
        )
        out = call(*args)
        return combine(out) if split else out

    build.program = prog
    build.block_config = (bm, bn)
    build.runtime_kv_len = runtime_kv
    build.paged = paged
    build.page_size = page
    build.chunk_prefill = chunked
    build.num_splits = ns
    build.kv_quant = kv_quant
    return build
