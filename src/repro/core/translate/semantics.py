"""Shared compute semantics for the TL ``Compute`` statements.

Both translation backends (pure-jnp oracle and Pallas kernel) lower each TL
``Compute`` to these functions, so the two backends agree by construction —
the operational meaning of a TL statement is defined exactly once.  This is
the repo's analogue of the paper's per-statement translation table
(TL statement -> CuTe code block, Figure 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite -inf stand-in; keeps exp()/max() NaN-free in bf16


def scale(s, factor):
    return s * factor


def mask_causal(s, q_pos, k_pos, q_off: int = 0):
    """q_pos: (BM, 1) absolute row ids; k_pos: (1, BN) absolute col ids.

    ``q_off = kv_len - q_len`` gives the FlashAttention-2 bottom-right
    alignment (query row i sits at absolute position ``q_off + i``), which
    is also what a prefill-with-prefix KV cache needs.
    """
    return jnp.where(k_pos <= q_pos + q_off, s, NEG_INF)


def mask_window(s, q_pos, k_pos, window: int, q_off: int = 0):
    return jnp.where(k_pos > q_pos + q_off - window, s, NEG_INF)


def mask_bounds(s, k_pos, kv_len):
    """Mask KV columns at or past ``kv_len``.

    ``kv_len`` is a python int for compile-time-length programs (wrapper
    pads N up to a multiple of BN) or a traced scalar for runtime-length
    decode programs (the true cache length inside a bucket, read from the
    kernel's SMEM operand).
    """
    return jnp.where(k_pos < kv_len, s, NEG_INF)


def online_softmax(s, m, l, acc):
    """One online-softmax step (the paper's ``Compute Online_softmax``).

    ``m``/``l`` carry the running row max / denominator, ``acc`` the
    un-normalised output accumulator; all f32.  ``m``/``l`` are stored
    lane-broadcast — shape (BM, LANE) with every column equal — matching the
    TL allocation ``Allocate m in register (BM, LANE)`` (TPU VREGs are
    (sublane, lane) tiles; a (BM, 1) vector would waste a full register tile
    anyway, so the broadcast costs nothing and keeps every op 2D).

    Returns ``(p, m_new, l_new, acc_rescaled)`` where ``p = exp(s - m_new)``.
    """

    m_cur = jnp.max(s, axis=-1, keepdims=True)          # (BM, 1)
    m_new = jnp.maximum(m[:, :1], m_cur)                # (BM, 1)
    alpha = jnp.exp(m[:, :1] - m_new)                   # (BM, 1)
    p = jnp.exp(s - m_new)                              # (BM, BN)
    # rows with no visible key yet (m_new still -inf) contribute nothing —
    # without this, exp(-inf - -inf) = 1 silently yields uniform attention
    p = jnp.where(m_new <= NEG_INF / 2, 0.0, p)
    l_new = l[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc * alpha
    lane = m.shape[-1]
    bcast = lambda x: jnp.broadcast_to(x, (x.shape[0], lane))
    return p, bcast(m_new), bcast(l_new), acc_new


def lse_merge(acc, m, l):
    """Merge per-split online-softmax partials (Flash-Decoding's combine).

    ``acc``: (S, ..., Dv) un-normalised accumulators; ``m``/``l``:
    (S, ..., W) running max / denominator with the row statistic in column
    0 (W is LANE for the kernels' lane-broadcast state, 1 for the XLA scan
    state).  Each split ran an independent online softmax over its KV
    partition; rescaling every partial to the global max and summing gives
    *exactly* the state one sequential pass over the whole KV would have
    produced, so the normal ``divide`` epilogue applies unchanged.

    Returns ``(acc, m, l)`` merged over the leading split axis, with
    ``m``/``l`` re-broadcast to width W.
    """
    m1 = m[..., :1]                                     # (S, ..., 1)
    m_max = jnp.max(m1, axis=0)                         # (..., 1)
    w = jnp.exp(m1 - m_max)
    # a split that saw no key (skipped blocks / fully masked) still holds
    # m == NEG_INF; zero its weight so the all-splits-dead case (row length
    # 0, where exp(NEG_INF - NEG_INF) == 1) contributes nothing
    w = jnp.where(m1 <= NEG_INF / 2, 0.0, w)
    acc_c = jnp.sum(w * acc, axis=0)
    l_c = jnp.sum(w * l[..., :1], axis=0)
    width = m.shape[-1]
    bcast = lambda x: jnp.broadcast_to(x, x.shape[:-1] + (width,))
    return acc_c, bcast(m_max), bcast(l_c)


def lse_merge_axis(acc, m, l, axis_name: str):
    """Shard-aware :func:`lse_merge` — merge online-softmax partials held
    by the ranks of a named mesh axis (inside ``shard_map``).

    Each rank ran an independent online softmax over its KV slice (a
    sequence shard of a replicated latent cache, or the local portion of a
    split-KV launch); ``all_gather`` stacks the per-rank ``(acc, m, l)``
    along a fresh leading axis and the ordinary :func:`lse_merge` reduces
    it — the same rescale-to-global-max algebra, so every rank computes the
    identical merged state deterministically (gather order is the fixed
    axis order, not arrival order).

    Returns the merged ``(acc, m, l)`` *without* dividing — callers hold
    arbitrary-rank state, and the epilogue divide stays theirs.
    """
    ga = lambda x: jax.lax.all_gather(x, axis_name, axis=0, tiled=False)
    return lse_merge(ga(acc), ga(m), ga(l))


def divide(acc, l):
    """Normalise the accumulator by the online-softmax denominator."""
    denom = l[:, :1]
    # guard fully-masked rows (padded q rows): denom == 0 -> output 0
    return acc / jnp.where(denom == 0.0, 1.0, denom)


def softmax(s):
    """Plain (non-online) softmax — used by naive TL variants."""
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
