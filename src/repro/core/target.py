"""Hardware target descriptors consumed by the TL translation stage.

The paper's translation stage takes "the necessary execution information
... for the specific hardware architecture" (CuTe MMA/Copy atoms on GPU).
On TPU the analogous information is the memory-hierarchy geometry (VMEM
capacity, lane/sublane tiling) and the MXU systolic-array shape.  The
translator and the autotuner both read a :class:`TPUTarget` instead of
hard-coding any of these, which is what makes the pipeline portable across
TPU generations the way the paper's prompt-swapping makes it portable
across GPU generations.
"""

from __future__ import annotations

import dataclasses


_DTYPE_BYTES = {
    "f32": 4, "float32": 4,
    "bf16": 2, "bfloat16": 2,
    "f16": 2, "float16": 2,
    "fp8": 1, "f8_e4m3": 1, "f8_e5m2": 1,
    "int8": 1, "i8": 1,
}


def dtype_bytes(dtype: str) -> int:
    return _DTYPE_BYTES[dtype.lower()]


@dataclasses.dataclass(frozen=True)
class TPUTarget:
    """Geometry + throughput description of one TPU core.

    ``sublane`` is the second-minor tile dimension for f32; narrower dtypes
    pack 2x/4x into the same physical tile (bf16 -> 16, int8/fp8 -> 32).
    """

    name: str
    vmem_bytes: int = 16 * 2**20          # v5e: 16 MiB VMEM per core
    hbm_bytes: int = 16 * 2**30           # v5e: 16 GiB HBM per chip
    mxu: tuple[int, int] = (128, 128)     # systolic array shape
    lane: int = 128                       # minor-dim tile
    sublane_f32: int = 8                  # second-minor tile at 4 bytes
    peak_bf16_tflops: float = 197.0       # per-chip peak
    hbm_gbps: float = 819.0               # HBM bandwidth GB/s
    ici_gbps: float = 50.0                # per-link ICI bandwidth GB/s
    supported_dtypes: tuple[str, ...] = ("f32", "bf16", "int8")
    # How many *parallel* grid programs the scheduler wants in flight to
    # fill the core (megacore halves + enough live DMA streams to hide
    # HBM latency).  The autotuner's split search (autotune.tune_splits,
    # consulted by reason.choose_num_splits) costs decode/verify waves of
    # `bsz * heads * splits` programs against this — the TPU analogue of
    # GPU FlashDecoding sizing splits to the SM count.  Calibration: the
    # latency-hiding stream count scales with HBM bandwidth per core
    # (~16 per 800 GB/s core at v5e's latency), doubled again by a
    # megacore's second TensorCore (v5p).
    decode_parallelism: int = 16
    # fraction of VMEM the autotuner may plan into (leave room for Mosaic's
    # own double-buffering of pipelined operands)
    vmem_budget_frac: float = 0.5

    def sublane(self, dtype: str) -> int:
        return self.sublane_f32 * (4 // max(1, dtype_bytes(dtype) // 1)) \
            if dtype_bytes(dtype) < 4 else self.sublane_f32

    def min_tile(self, dtype: str) -> tuple[int, int]:
        """Minimum (second-minor, minor) tile for ``dtype``."""
        packing = 4 // dtype_bytes(dtype)
        return (self.sublane_f32 * max(1, packing), self.lane)

    def supports(self, dtype: str) -> bool:
        return dtype.lower() in self.supported_dtypes

    @property
    def vmem_budget(self) -> int:
        return int(self.vmem_bytes * self.vmem_budget_frac)


# Registry of targets the translator knows how to describe.  ``cpu-interp``
# mirrors v5e geometry but marks kernels for interpret-mode execution (this
# container); fp8 is listed for v6e-style parts the way the paper's case
# study extends to FP8 on L40S.
TARGETS: dict[str, TPUTarget] = {
    "v5e": TPUTarget(name="v5e"),
    "v5p": TPUTarget(
        name="v5p",
        vmem_bytes=16 * 2**20,
        hbm_bytes=95 * 2**30,
        peak_bf16_tflops=459.0,
        hbm_gbps=2765.0,
        ici_gbps=100.0,
        decode_parallelism=32,            # megacore: two TensorCores/chip
    ),
    "v6e": TPUTarget(
        name="v6e",
        vmem_bytes=32 * 2**20,
        hbm_bytes=32 * 2**30,
        peak_bf16_tflops=918.0,
        hbm_gbps=1640.0,
        supported_dtypes=("f32", "bf16", "int8", "fp8"),
        decode_parallelism=32,            # 2x v5e HBM bandwidth per core
    ),
    "cpu-interp": TPUTarget(name="cpu-interp"),
}


def get_target(name: str) -> TPUTarget:
    try:
        return TARGETS[name]
    except KeyError:
        raise KeyError(f"unknown target {name!r}; known: {sorted(TARGETS)}") from None
