"""Canonical TL text printer — inverse of :mod:`repro.core.tl.parser`.

``parse(print(prog))`` round-trips (property-tested in
``tests/test_tl_language.py``), which is what lets the deterministic and
LLM-driven generator backends exchange programs as plain text, exactly as
the paper's workflow does between its two stages.
"""

from __future__ import annotations

from .ast import (
    Allocate,
    ComputeGEMM,
    ComputeOp,
    Copy,
    ForLoop,
    If,
    Reshape,
    Statement,
    TLProgram,
)

_INDENT = "    "


def _dims(shape) -> str:
    return ", ".join(str(d) for d in shape)


def _stmt_lines(stmt: Statement, depth: int) -> list[str]:
    pad = _INDENT * depth
    if isinstance(stmt, Allocate):
        line = f"Allocate {stmt.name} in {stmt.space} ({_dims(stmt.shape)})"
        if stmt.offset:
            line += f" with offset {stmt.offset}"
        if stmt.dtype != "bf16":
            line += f" as {stmt.dtype}"
        return [pad + line]
    if isinstance(stmt, Copy):
        line = f"Copy {stmt.name}"
        if stmt.shape:
            line += f" ({_dims(stmt.shape)})"
        if stmt.coords:
            inner = ", ".join(f"{k} = {v}" for k, v in stmt.coords.items())
            line += f" in coordinate [{inner}]"
        line += f" from {stmt.src} to {stmt.dst}"
        return [pad + line]
    if isinstance(stmt, ComputeGEMM):
        mode = "accumulate" if stmt.accumulate else "get"
        return [pad + f"Compute GEMM {stmt.a}, {stmt.b} and {mode} {stmt.out}"]
    if isinstance(stmt, ComputeOp):
        line = f"Compute {stmt.op.capitalize()} {', '.join(stmt.args)}"
        if stmt.out:
            mode = "accumulate" if stmt.accumulate else "get"
            line += f" and {mode} {stmt.out}"
        return [pad + line]
    if isinstance(stmt, Reshape):
        return [pad + f"Reshape {stmt.name} from {stmt.from_layout} to {stmt.to_layout}"]
    if isinstance(stmt, ForLoop):
        lines = [pad + f"for {stmt.var} = {stmt.start}:{stmt.end}"]
        for s in stmt.body:
            lines.extend(_stmt_lines(s, depth + 1))
        lines.append(pad + "end")
        return lines
    if isinstance(stmt, If):
        lines = [pad + f"if {stmt.cond}"]
        for s in stmt.body:
            lines.extend(_stmt_lines(s, depth + 1))
        lines.append(pad + "end")
        return lines
    raise TypeError(f"unknown TL statement {stmt!r}")


def to_text(prog: TLProgram) -> str:
    lines: list[str] = [f"// TL program: {prog.name}"]
    if prog.params:
        lines.append(
            "// params: " + ", ".join(f"{k}={v}" for k, v in sorted(prog.params.items()))
        )
    for stmt in prog.body:
        lines.extend(_stmt_lines(stmt, 0))
    return "\n".join(lines) + "\n"
