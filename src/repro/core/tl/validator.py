"""Statement-level TL validation.

The paper's central reliability claim is that hierarchical generation plus
per-statement checking eliminates the two characteristic one-stage failure
modes (Appendix B):

* **Reshape omission** (Listing 1) — chaining two GEMMs without re-declaring
  the first accumulator's layout as an input-operand layout; and
* **GEMM layout error** (Listing 2) — conflating TL-level transpose notation
  with the physical layout, producing a contraction-dimension mismatch.

This module is that checker, plus the TPU-specific structural checks the
translation stage relies on (allocation discipline, VMEM footprint,
MXU/lane alignment, output write-back).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..target import TPUTarget, dtype_bytes, get_target
from .ast import (
    Allocate,
    ComputeGEMM,
    ComputeOp,
    Copy,
    Dim,
    ForLoop,
    If,
    MemSpace,
    Reshape,
    Statement,
    TLProgram,
)

_SPACE_SUFFIXES = ("_shared", "_register", "_reg", "_global")


def base_name(ref: str) -> str:
    """``K_shared`` -> ``K`` (the paper suffixes names with the tier)."""
    for suf in _SPACE_SUFFIXES:
        if ref.endswith(suf):
            return ref[: -len(suf)]
    return ref


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    code: str          # E001..E0xx errors, W0xx warnings
    message: str
    stmt: Optional[Statement] = None

    @property
    def is_error(self) -> bool:
        return self.code.startswith("E")

    def __str__(self) -> str:
        return f"[{self.code}] {self.message}"


class TLValidationError(ValueError):
    def __init__(self, diagnostics: list[Diagnostic]):
        self.diagnostics = diagnostics
        super().__init__(
            "TL validation failed:\n" + "\n".join(f"  {d}" for d in diagnostics)
        )


def _dims_eq(a: Dim, b: Dim, params: dict) -> Optional[bool]:
    """Symbolic dim equality; None when undecidable."""

    def val(d):
        if isinstance(d, int):
            return d
        return params.get(d)

    va, vb = val(a), val(b)
    if va is not None and vb is not None:
        return int(va) == int(vb)
    if isinstance(a, str) and isinstance(b, str):
        return a == b if a == b else None
    return None


class _ShapeEnv:
    """Symbolic shape propagation through the statement stream."""

    def __init__(self, prog: TLProgram):
        self.params = prog.params
        self.shapes: dict[str, tuple[Dim, ...]] = {}
        self.dtypes: dict[str, str] = {}
        for a in prog.find(Allocate):
            self.shapes[a.name] = tuple(a.shape)
            self.dtypes[a.name] = a.dtype

    def get(self, ref: str) -> Optional[tuple[Dim, ...]]:
        return self.shapes.get(base_name(ref))

    def set(self, ref: str, shape: tuple[Dim, ...]) -> None:
        self.shapes[base_name(ref)] = shape


def validate(
    prog: TLProgram,
    target: TPUTarget | str = "v5e",
    *,
    strict_alloc: Optional[bool] = None,
) -> list[Diagnostic]:
    """Return all diagnostics for ``prog`` (errors + warnings).

    ``strict_alloc`` defaults to True for reasoned TL code and False for
    sketches (stage recorded in ``prog.meta``), since sketches legitimately
    omit allocations and parameters.
    """

    if isinstance(target, str):
        target = get_target(target)
    if strict_alloc is None:
        strict_alloc = prog.meta.get("stage", "code") != "sketch"

    diags: list[Diagnostic] = []
    env = _ShapeEnv(prog)
    flat = list(prog.walk())

    # ---- E003: allocation discipline ---------------------------------------
    if strict_alloc:
        for s in flat:
            if isinstance(s, Copy) and env.get(s.name) is None:
                diags.append(Diagnostic(
                    "E003", f"Copy of unallocated tensor {s.name!r}", s))
            if isinstance(s, Copy) and s.shape is None:
                diags.append(Diagnostic(
                    "E003", f"Copy of {s.name!r} missing block shape "
                            "(parameter reasoning incomplete)", s))

    # ---- dataflow walk: E001 / E002 / shape propagation ---------------------
    produced_by_gemm: set[str] = set()
    reshaped: set[str] = set()

    def walk(stmts: list[Statement]) -> None:
        for s in stmts:
            if isinstance(s, (ForLoop, If)):
                walk(s.body)
                continue
            if isinstance(s, Reshape):
                reshaped.add(base_name(s.name))
                continue
            if isinstance(s, Copy):
                # after an HBM->VMEM copy the on-chip tensor has block shape
                if s.shape is not None and s.dst is not MemSpace.GLOBAL:
                    env.set(s.name, tuple(s.shape))
                continue
            if isinstance(s, ComputeGEMM):
                _check_gemm(s)
                produced_by_gemm.add(base_name(s.out))
                reshaped.discard(base_name(s.out))
                continue
            if isinstance(s, ComputeOp):
                _propagate_op(s)
                continue

    def _check_gemm(s: ComputeGEMM) -> None:
        a_name, b_name = base_name(s.a.name), base_name(s.b.name)
        # E001 — reshape omission on a fused operand (TL *code* only: a
        # sketch legitimately defers the Reshape to the reasoning stage)
        for opname, nm in (("A", a_name), ("B", b_name)):
            if strict_alloc and nm in produced_by_gemm and nm not in reshaped:
                diags.append(Diagnostic(
                    "E001",
                    f"GEMM {opname}-operand {nm!r} is produced by a previous "
                    f"GEMM (accumulator layout) but was never Reshape'd to an "
                    f"operand layout — reshape omission (paper App. B, "
                    f"Listing 1)", s))
        # E002 — contraction-dimension / layout error
        sa, sb = env.get(s.a.name), env.get(s.b.name)
        if sa is not None and sb is not None and len(sa) == 2 and len(sb) == 2:
            ka = sa[0] if s.a.transposed else sa[1]
            kb = sb[1] if s.b.transposed else sb[0]
            eq = _dims_eq(ka, kb, prog.params)
            if eq is False or (eq is None and isinstance(ka, str)
                               and isinstance(kb, str) and ka != kb):
                diags.append(Diagnostic(
                    "E002",
                    f"GEMM {s.a} @ {s.b}: contraction dims {ka!r} vs {kb!r} "
                    f"do not match — GEMM layout error (paper App. B, "
                    f"Listing 2); check transpose notation", s))
            m = sa[1] if s.a.transposed else sa[0]
            n = sb[0] if s.b.transposed else sb[1]
            if env.get(s.out) is None:
                env.set(s.out, (m, n))
        # W002 — accumulation into non-f32
        out_dtype = env.dtypes.get(base_name(s.out))
        if s.accumulate and out_dtype not in (None, "f32", "float32"):
            diags.append(Diagnostic(
                "W002",
                f"GEMM accumulates into {s.out!r} of dtype {out_dtype}; MXU "
                f"accumulation should be f32", s))

    def _propagate_op(s: ComputeOp) -> None:
        if s.op == "slice" and len(s.args) >= 3 and s.out:
            src = env.get(s.args[0])
            if src is not None:
                lo = s.args[1]
                hi = s.args[2]
                width: Dim = hi if str(lo) == "0" else f"{hi}-{lo}"
                env.set(s.out, (src[0], width))
        elif s.out:
            src = env.get(s.args[0]) if s.args else None
            if src is not None and env.get(s.out) is None:
                env.set(s.out, src)
        # taint: out derived from a GEMM product keeps accumulator layout
        if s.out and any(base_name(a) in produced_by_gemm for a in s.args):
            produced_by_gemm.add(base_name(s.out))

    walk(prog.body)

    # ---- E005: outputs written back -----------------------------------------
    for out in prog.outputs:
        wrote = any(
            isinstance(s, Copy) and base_name(s.name) == out
            and s.dst is MemSpace.GLOBAL
            for s in flat
        )
        if not wrote:
            diags.append(Diagnostic(
                "E005", f"output {out!r} is never copied back to global"))

    # ---- E004 / W001: VMEM footprint + alignment (needs resolved params) ----
    try:
        vmem = 0
        for a in prog.find(Allocate):
            if a.space is MemSpace.GLOBAL:
                continue
            n = 1
            for d in a.shape:
                n *= prog.resolve(d)
            mult = 2 if a.space is MemSpace.SHARED else 1  # double-buffer
            vmem += n * dtype_bytes(a.dtype) * mult
            dims = [prog.resolve(d) for d in a.shape]
            if len(dims) >= 1 and dims[-1] % target.lane and dims[-1] >= target.lane:
                diags.append(Diagnostic(
                    "W001", f"{a.name}: minor dim {dims[-1]} not a multiple "
                            f"of lane={target.lane}", a))
            sub = target.min_tile(a.dtype)[0]
            if len(dims) >= 2 and dims[-2] % sub and dims[-2] >= sub:
                diags.append(Diagnostic(
                    "W001", f"{a.name}: second-minor dim {dims[-2]} not a "
                            f"multiple of sublane={sub}", a))
        if vmem > target.vmem_budget:
            diags.append(Diagnostic(
                "E004", f"on-chip working set {vmem/2**20:.2f} MiB exceeds "
                        f"VMEM budget {target.vmem_budget/2**20:.2f} MiB on "
                        f"{target.name}"))
    except KeyError:
        if strict_alloc:
            diags.append(Diagnostic(
                "E006", "TL code has unbound symbolic dimensions; parameter "
                        "reasoning incomplete"))

    return diags


def check(prog: TLProgram, target: TPUTarget | str = "v5e", **kw) -> None:
    """Raise :class:`TLValidationError` if ``prog`` has any errors."""

    errs = [d for d in validate(prog, target, **kw) if d.is_error]
    if errs:
        raise TLValidationError(errs)
