from .ast import (  # noqa: F401
    Allocate,
    ComputeGEMM,
    ComputeOp,
    Copy,
    ForLoop,
    If,
    MemSpace,
    Reshape,
    Statement,
    TensorRef,
    TLProgram,
)
from .parser import TLSyntaxError, parse  # noqa: F401
from .printer import to_text  # noqa: F401
from .validator import (  # noqa: F401
    Diagnostic,
    TLValidationError,
    base_name,
    check,
    validate,
)
