"""LLM-TL abstract syntax tree.

The paper's Thinking Language (LLM-TL) abstracts an operator's execution on
an accelerator into two statement families — ``Copy`` (data movement between
memory tiers) and ``Compute`` (tile computations) — plus the support
statements ``Allocate``, ``Reshape``, ``For`` and ``If`` that appear in the
paper's listings.  A :class:`TLProgram` is an ordered list of statements with
a symbolic parameter environment (``BM``, ``BN``, ``HeadDim``, ...).

Dimensions are symbolic strings resolved against ``TLProgram.params`` so the
same program text can be re-parameterised by the autotuner (the paper's
"Parameter Analysis and Reasoning" stage) without regenerating the sketch.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Optional, Sequence, Union


class MemSpace(enum.Enum):
    """TPU re-grounding of the paper's GPU memory tiers (DESIGN.md §2)."""

    GLOBAL = "global"      # HBM
    SHARED = "shared"      # VMEM
    REGISTER = "register"  # VREG-resident tile values / VMEM scratch accumulators

    def __str__(self) -> str:  # pragma: no cover - trivial
        return self.value


# A dimension is either a literal int or a symbolic name like "BM".
Dim = Union[int, str]


def resolve_dim(dim: Dim, params: dict) -> int:
    if isinstance(dim, int):
        return dim
    if isinstance(dim, str) and dim.isdigit():
        return int(dim)
    if dim in params:
        return int(params[dim])
    raise KeyError(f"unbound TL dimension {dim!r}; params={sorted(params)}")


@dataclasses.dataclass(frozen=True)
class TensorRef:
    """A named tensor, optionally marked transposed (paper: ``K_shared.T``)."""

    name: str
    transposed: bool = False

    def __str__(self) -> str:
        return f"{self.name}.T" if self.transposed else self.name


@dataclasses.dataclass
class Allocate:
    """``Allocate A in global (M, K) with offset head_offset``"""

    name: str
    space: MemSpace
    shape: tuple[Dim, ...]
    dtype: str = "bf16"
    offset: Optional[str] = None  # symbolic base-offset expression


@dataclasses.dataclass
class Copy:
    """``Copy K (BN, HeadDim) in coordinate [L = i] from global to shared``

    ``shape``/``coords`` are ``None`` in the sketch stage; the reasoning
    stage (paper §3.2.2) fills them in.  ``coords`` maps loop-axis label →
    index expression (e.g. ``{"L": "i"}``).
    """

    name: str
    src: MemSpace
    dst: MemSpace
    shape: Optional[tuple[Dim, ...]] = None
    coords: Optional[dict[str, str]] = None


@dataclasses.dataclass
class ComputeGEMM:
    """``Compute GEMM A, B and get S`` / ``... and accumulate S``"""

    a: TensorRef
    b: TensorRef
    out: str
    accumulate: bool = False


@dataclasses.dataclass
class ComputeOp:
    """Non-GEMM compute: ``Compute <op> <args...> and get <out>``.

    Covers the paper's "regular computation" and "other operators":
    softmax, online-softmax update, masking, scaling, elementwise math.
    When ``out`` is None the op updates its first argument in place
    (paper: ``Compute Softmax S``).
    """

    op: str                      # e.g. softmax, online_softmax, mask_causal,
                                 # multiply, divide, add, subtract, exp, max, scale
    args: tuple[str, ...]        # operand names (or scalar symbols)
    out: Optional[str] = None
    accumulate: bool = False


@dataclasses.dataclass
class Reshape:
    """``Reshape S from acc_layout to operand_layout``

    The paper's critical fusion statement: between two chained GEMMs the
    first GEMM's accumulator tile must be re-declared in the layout the
    second GEMM expects (mma_C→mma_A on Tensor Cores; f32-accumulator →
    input-dtype operand tile on the MXU).
    """

    name: str
    from_layout: str
    to_layout: str


@dataclasses.dataclass
class ForLoop:
    """``for i = 0:N`` ... ``end`` — N may be symbolic (e.g. "Tkv")."""

    var: str
    start: Dim
    end: Dim
    body: list["Statement"] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class If:
    """``if <cond>`` ... ``end`` — condition is a symbolic expression."""

    cond: str
    body: list["Statement"] = dataclasses.field(default_factory=list)


Statement = Union[Allocate, Copy, ComputeGEMM, ComputeOp, Reshape, ForLoop, If]


@dataclasses.dataclass
class TLProgram:
    """A complete TL code unit (sketch when parameters are unfilled)."""

    name: str
    body: list[Statement]
    params: dict = dataclasses.field(default_factory=dict)
    # names of tensors that are kernel inputs / outputs in GLOBAL space
    inputs: tuple[str, ...] = ()
    outputs: tuple[str, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)

    # ---- traversal helpers -------------------------------------------------
    def walk(self) -> Iterator[Statement]:
        """Yield statements in program order, descending into loop bodies."""

        def _walk(stmts: Sequence[Statement]) -> Iterator[Statement]:
            for s in stmts:
                yield s
                if isinstance(s, (ForLoop, If)):
                    yield from _walk(s.body)

        yield from _walk(self.body)

    def allocations(self) -> dict[str, Allocate]:
        return {s.name: s for s in self.walk() if isinstance(s, Allocate)}

    def find(self, cls) -> list:
        return [s for s in self.walk() if isinstance(s, cls)]

    def resolve(self, dim: Dim) -> int:
        return resolve_dim(dim, self.params)
