"""Parser for the LLM-TL textual syntax used in the paper's listings.

Grammar (line-oriented; ``//`` comments; blocks closed by ``end``)::

    Allocate <name> in <space> (<dims>) [with offset <expr>] [as <dtype>]
    Copy <name> [(<dims>)] [in coordinate [<axis> = <expr>, ...]] from <space> to <space>
    Compute GEMM <a>[.T], <b>[.T] and (get|accumulate) <out>
    Compute <Op> <arg>[, <arg>...] [and (get|accumulate) [new] <out>] [with <arg> and <arg>]
    Reshape <name> from <layout> to <layout>
    for <var> (=|in) <start>:<end>
    if <cond>
    end

The parser is deliberately forgiving about whitespace/case so that TL text
produced by an LLM backend round-trips; the *validator* is where strictness
lives (the paper's Appendix-B failure modes are caught there, not here).
"""

from __future__ import annotations

import re
from typing import Optional

from .ast import (
    Allocate,
    ComputeGEMM,
    ComputeOp,
    Copy,
    ForLoop,
    If,
    MemSpace,
    Reshape,
    Statement,
    TensorRef,
    TLProgram,
)


class TLSyntaxError(ValueError):
    def __init__(self, line_no: int, line: str, msg: str):
        super().__init__(f"TL syntax error at line {line_no}: {msg}\n  {line}")
        self.line_no = line_no


_DIM = r"[A-Za-z_][A-Za-z0-9_]*|\d+"

_ALLOCATE = re.compile(
    rf"^Allocate\s+(?P<name>\w+)\s+in\s+(?P<space>global|shared|register)\s*"
    rf"\((?P<dims>[^)]*)\)"
    rf"(?:\s+with\s+offset\s+(?P<offset>[\w+*/\- ()\[\].]+?))?"
    rf"(?:\s+as\s+(?P<dtype>\w+))?\s*$",
    re.IGNORECASE,
)

_COPY = re.compile(
    rf"^Copy\s+(?P<name>\w+)"
    rf"(?:\s*\((?P<dims>[^)]*)\))?"
    rf"(?:\s+in\s+coord(?:inate)?\s*\[(?P<coords>[^\]]*)\])?"
    rf"\s+from\s+(?P<src>global|shared|register)"
    rf"(?:\s+memory)?\s+to\s+(?P<dst>global|shared|register)(?:\s+memory)?\s*$",
    re.IGNORECASE,
)

_GEMM = re.compile(
    r"^Compute\s+GEMM\s+(?P<a>\w+(?:\.T)?)\s*,\s*(?P<b>\w+(?:\.T)?)\s+and\s+"
    r"(?P<mode>get|accumulate)\s+(?:new\s+)?(?P<out>\w+)\s*$",
    re.IGNORECASE,
)

_COMPUTE = re.compile(
    r"^Compute\s+(?P<op>\w+)\s+(?P<args>[\w., ]+?)"
    r"(?:\s+and\s+(?P<mode>get|accumulate)\s+(?P<new>new\s+)?(?P<out>\w+))?"
    r"(?:\s+with\s+(?P<with>[\w, ]+?))?"
    r"(?:\s+rescaling\s+(?P<rescale>\w+))?\s*$",
    re.IGNORECASE,
)

_RESHAPE = re.compile(
    r"^Reshape\s+(?P<name>\w+)\s+from\s+(?P<frm>\([^)]*\)|[\w]+)\s+to\s+"
    r"(?P<to>\([^)]*\)|[\w]+)\s*$",
    re.IGNORECASE,
)

_FOR = re.compile(
    rf"^for\s+(?P<var>\w+)\s*(?:=|\bin\b)\s*(?P<start>{_DIM})\s*:\s*"
    rf"(?P<end>[\w+*/\-() ]+?)\s*:?\s*$",
    re.IGNORECASE,
)

_IF = re.compile(r"^if\s+(?P<cond>.+?)\s*$", re.IGNORECASE)
_END = re.compile(r"^end\s*$", re.IGNORECASE)


def _parse_dims(text: str) -> tuple:
    dims = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        dims.append(int(part) if part.isdigit() else part)
    return tuple(dims)


def _parse_coords(text: str) -> dict[str, str]:
    coords: dict[str, str] = {}
    for part in text.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            coords[k.strip()] = v.strip()
    return coords


def _tensor_ref(text: str) -> TensorRef:
    text = text.strip()
    if text.endswith(".T"):
        return TensorRef(text[:-2], transposed=True)
    return TensorRef(text)


def parse_statement(line: str, line_no: int = 0) -> Optional[Statement]:
    """Parse one TL line; returns None for blanks/comments, 'END' sentinel
    is handled by :func:`parse`."""

    m = _ALLOCATE.match(line)
    if m:
        return Allocate(
            name=m["name"],
            space=MemSpace(m["space"].lower()),
            shape=_parse_dims(m["dims"]),
            dtype=(m["dtype"] or "bf16").lower(),
            offset=m["offset"].strip() if m["offset"] else None,
        )
    m = _COPY.match(line)
    if m:
        return Copy(
            name=m["name"],
            src=MemSpace(m["src"].lower()),
            dst=MemSpace(m["dst"].lower()),
            shape=_parse_dims(m["dims"]) if m["dims"] else None,
            coords=_parse_coords(m["coords"]) if m["coords"] else None,
        )
    m = _GEMM.match(line)
    if m:
        return ComputeGEMM(
            a=_tensor_ref(m["a"]),
            b=_tensor_ref(m["b"]),
            out=m["out"],
            accumulate=m["mode"].lower() == "accumulate",
        )
    m = _RESHAPE.match(line)
    if m:
        return Reshape(name=m["name"], from_layout=m["frm"], to_layout=m["to"])
    m = _COMPUTE.match(line)
    if m:
        args = tuple(a.strip() for a in m["args"].split(",") if a.strip())
        if m["with"]:
            args = args + tuple(a.strip() for a in m["with"].split(",") if a.strip())
        if m["rescale"]:
            args = args + (m["rescale"],)
        out = m["out"]
        # "get new A" vs in-place "get A" both write A; the distinction is
        # kept in ComputeOp.out either way.
        return ComputeOp(
            op=m["op"].lower(),
            args=args,
            out=out,
            accumulate=bool(m["mode"] and m["mode"].lower() == "accumulate"),
        )
    raise TLSyntaxError(line_no, line, "unrecognised TL statement")


def parse(text: str, name: str = "tl_program", params: Optional[dict] = None) -> TLProgram:
    """Parse a full TL program from text."""

    root: list[Statement] = []
    stack: list[list[Statement]] = [root]

    for line_no, raw in enumerate(text.splitlines(), start=1):
        line = raw.split("//")[0].strip()
        if not line:
            continue
        if _END.match(line):
            if len(stack) == 1:
                raise TLSyntaxError(line_no, raw, "'end' without open block")
            stack.pop()
            continue
        m = _FOR.match(line)
        if m:
            end_dim = m["end"].strip()
            loop = ForLoop(
                var=m["var"],
                start=int(m["start"]) if m["start"].isdigit() else m["start"],
                end=int(end_dim) if end_dim.isdigit() else end_dim,
            )
            stack[-1].append(loop)
            stack.append(loop.body)
            continue
        m = _IF.match(line)
        if m and not line.lower().startswith(("if_", "ifft")):
            node = If(cond=m["cond"])
            stack[-1].append(node)
            stack.append(node.body)
            continue
        stmt = parse_statement(line, line_no)
        if stmt is not None:
            stack[-1].append(stmt)

    if len(stack) != 1:
        raise TLSyntaxError(-1, "", f"{len(stack) - 1} unclosed block(s)")

    prog = TLProgram(name=name, body=root, params=dict(params or {}))
    allocs = prog.allocations()
    prog.inputs = tuple(
        n for n, a in allocs.items() if a.space is MemSpace.GLOBAL
    )
    return prog
