"""The paper's contribution: the LLM-TL thinking language, the 2-stage
TL-code generation/translation workflow, and the self-optimizing attention
kernel pipeline (sketch -> reason -> validate -> translate)."""

from .autotune import tune  # noqa: F401
from .llm import DeterministicBackend, GeneratorBackend, OneStageBackend  # noqa: F401
from .pipeline import GeneratedKernel, cached_kernel, generate_attention_kernel  # noqa: F401
from .spec import AttnSpec  # noqa: F401
from .target import TARGETS, TPUTarget, get_target  # noqa: F401
