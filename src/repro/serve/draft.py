"""Draft-token proposers for speculative decoding.

The serving engine's speculative path (``ServeEngine(spec_decode=True)``)
splits each decode step in two: a cheap *draft* source proposes up to K
continuation tokens, and one batched ``verify`` dispatch scores all of
them in a single TL kernel launch (see ``core/spec.py`` mode="verify").
This module is the draft side.

The default source is *self-speculative*: :class:`NgramProposer` does
prompt-lookup decoding (Saxena; "Prompt Lookup Decoding") over the
request's own token history — no second model, no extra params, no
extra HBM.  When the tail n-gram of the history has appeared before,
the tokens that followed that earlier occurrence are proposed verbatim.
On repetitive continuations (code, structured text, retrieval-heavy
prompts) acceptance is high; on novel text it degrades to zero accepted
drafts, which the engine bounds to one wasted verify lane per step.

Anything with a ``propose(uid, history, k)`` method is a valid source
(:class:`DraftProposer`), so a small draft *model* can slot in: load a
reduced config from ``configs/`` (``registry.get_reduced``), run its own
greedy decode for k tokens, and return them — the engine never looks at
how the drafts were produced, only whether the target model's verify
logits agree.
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable


@runtime_checkable
class DraftProposer(Protocol):
    """Draft source contract for the engine's speculative decode path."""

    def propose(self, uid: int, history: Sequence[int],
                k: int) -> list[int]:
        """Up to ``k`` draft tokens continuing ``history`` (the request's
        prompt plus everything committed so far, including the token the
        engine just sampled).  Fewer than ``k`` — including none — is
        always legal; the engine verifies whatever comes back."""
        ...


class NgramProposer:
    """Prompt-lookup drafts: match the longest tail n-gram of the history
    earlier in the history and propose the tokens that followed it.

    ``max_n`` down to ``min_n`` tail lengths are tried longest-first (a
    longer match is stronger evidence the continuation repeats); within
    one n the *most recent* earlier occurrence wins (locality: recent
    repeats track the current phrasing better than distant ones).  Cost
    is O(len(history) * max_n) per call in the worst case — draft-side
    work is Python-cheap by design; the accelerator only ever runs the
    single verify dispatch.
    """

    def __init__(self, max_n: int = 4, min_n: int = 1):
        if not 1 <= min_n <= max_n:
            raise ValueError(f"need 1 <= min_n <= max_n, got "
                             f"min_n={min_n} max_n={max_n}")
        self.max_n = int(max_n)
        self.min_n = int(min_n)

    def propose(self, uid: int, history: Sequence[int],
                k: int) -> list[int]:
        h = list(history)
        if k <= 0 or len(h) < self.min_n + 1:
            return []
        for n in range(min(self.max_n, len(h) - 1), self.min_n - 1, -1):
            tail = h[-n:]
            # scan right-to-left over earlier occurrences, excluding the
            # tail itself (i + n <= len(h) - 1 keeps >= 1 follow token)
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == tail:
                    out = h[i + n:i + n + k]
                    if out:
                        return out
        return []


def make_proposer(name: str = "ngram", **kwargs) -> DraftProposer:
    """Draft-source factory (the knob ``ServeEngine(draft_proposer=...)``
    resolves string specs through).  ``"ngram"`` is the only built-in;
    a draft-model source belongs here once a reduced target from
    ``configs/`` is wired up as a proposer."""
    if name == "ngram":
        return NgramProposer(**kwargs)
    raise ValueError(
        f"unknown draft proposer {name!r}; built-ins: ['ngram'] — for a "
        "draft model, wrap a reduced config from configs/ in an object "
        "with a propose(uid, history, k) method")
