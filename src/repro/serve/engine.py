"""Batched serving engine: prefill + decode with persistent KV caches.

Length bucketing keeps jit cache size bounded (prompt lengths are padded up
to power-of-two buckets; decode is a single (B, 1) step shape).  Greedy and
temperature sampling.  The engine is mesh-agnostic: pass ``shardings`` for
params/caches to serve on a pjit mesh, or nothing for single-device.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig


def _bucket(n: int, lo: int = 64) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray          # (B, new)
    prompt_len: list[int]
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 2048, vision_embeds=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.vision = vision_embeds

        @functools.partial(jax.jit, static_argnames=("prompt_pad",))
        def prefill(params, tokens, caches, prompt_pad):
            logits, _, caches = transformer.apply(
                params, tokens, cfg, caches=caches, cache_len=0,
                vision_embeds=self.vision)
            return logits, caches

        # cache_len is static: the TL-Pallas decode kernel is specialised
        # per KV length.  Production serving buckets decode lengths (e.g.
        # powers of two) to bound recompilation; tests take the per-step
        # retrace.
        @functools.partial(jax.jit, static_argnames=("cache_len",))
        def decode(params, tok, caches, cache_len):
            logits, _, caches = transformer.apply(
                params, tok, cfg, caches=caches, cache_len=cache_len,
                vision_embeds=self.vision)
            return logits[:, -1], caches

        self._prefill = prefill
        self._decode = decode

    def generate(self, prompts: list[list[int]], *, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0) -> GenResult:
        """Greedy/temperature generation for a batch of prompts."""
        if len(prompts) > self.max_batch:
            raise ValueError(f"batch {len(prompts)} > max_batch "
                             f"{self.max_batch}")
        b = len(prompts)
        lens = [len(p) for p in prompts]
        if len(set(lens)) != 1:
            raise ValueError(
                "ServeEngine batches must be length-homogeneous; group "
                f"requests by prompt length (got {sorted(set(lens))})")
        # exact-length prefill: recurrent archs (RWKV/Mamba) carry state, so
        # right-padding would contaminate it; one jit entry per distinct
        # prompt length (group-by-length batching bounds this in practice)
        pad_to = lens[0]
        toks = np.asarray(prompts, np.int32)

        caches = transformer.init_caches(self.cfg, b, self.max_len)
        logits, caches = self._prefill(self.params, jnp.asarray(toks),
                                       caches, prompt_pad=pad_to)
        # next-token logits come from each prompt's true last position
        last = jnp.asarray([l - 1 for l in lens])
        step_logits = logits[jnp.arange(b), last]

        key = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new_tokens), np.int32)
        cache_len = lens[0]
        tok = None
        for t in range(max_new_tokens):
            if temperature > 0.0:
                key, k2 = jax.random.split(key)
                tok = jax.random.categorical(
                    k2, step_logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(step_logits, axis=-1)
            out[:, t] = np.asarray(tok)
            step_logits, caches = self._decode(
                self.params, tok[:, None].astype(jnp.int32), caches,
                cache_len)
            cache_len += 1
        return GenResult(tokens=out, prompt_len=lens, steps=max_new_tokens)
