"""Batched serving engine: prefill + bucketed runtime-length decode over a
paged KV cache.

The decode step is compiled per power-of-two *length bucket*, not per cache
length: ``cache_len`` is a traced per-request vector and the bucket (the
number of cache entries attention reads) is the only static shape input.
The jit cache is therefore bounded at O(log2(max_len)) decode entries
instead of one per generated token — the FlashDecoding-style serving
contract over the TL-generated runtime-length kernels.

KV storage for the ``submit()``/``step()`` path is *paged*: instead of one
dense ``(max_batch, Hkv, max_len, D)`` reservation per slot, every
attention layer owns a pool of fixed-size pages and a :class:`PageAllocator`
hands them out — ``ceil(len / page_size)`` pages per request, allocated on
write as the request grows and freed when it retires.  A request therefore
reserves HBM proportional to its *true* length, admitted-request capacity
is bounded by total pages rather than ``max_batch x max_len``, and the
per-row block table rides into the decode kernel as a runtime operand (the
TL paged-decode layout).  When the pool runs dry mid-decode the
lowest-priority-then-youngest request is preempted — its pages are freed
and it re-queues (in admission order among victims) for re-prefill — so
neighbours' pages are never corrupted.

Admission itself can be *budgeted* (``prefill_budget``): instead of
prefilling a whole prompt before decode resumes, each step spends at most
that many prompt tokens on page-aligned chunk-prefill dispatches
interleaved with the decode batch (Sarathi-style chunked prefill), so one
long prompt never stalls the running requests — the decode-latency SLO the
scheduler exists for.  Mid-prefill rows ride the decode step masked at
length zero with their table remapped to the reserved dump page; a prompt
whose last chunk lands joins the decode batch the same step.  Full pages
are published to the prefix index *as they are written*, and every
prefilling request re-probes the index before each chunk — identical or
shared-prefix prompts admitted together therefore prefill once (the
follower adopts the leader's pages, radix-style, mid-flight).

Pages are *shared and ref-counted*: the allocator keeps a
content-addressed prefix index (page-aligned token chunk chains -> page),
``submit()``-admission matches each prompt against it and maps cached
pages into the request's block table instead of recomputing them, and any
write into a page another holder still references copies the page first
(copy-on-write) — pages return to circulation only at refcount zero.
Retired requests' indexed pages linger in an LRU evictable set, so a
prefix can hit after its originator is long gone; the pool reclaims them
under pressure.

Admission prefill runs *chunked directly into the pages*: the prompt (or
its un-matched suffix) is processed in page-aligned chunks through the TL
chunked-prefill kernel path — each chunk's K/V is scattered into the
block-table pages, then the chunk attends causally to everything written
so far — so long prompts have bounded peak memory and there is no dense
prefill buffer to scatter from.

Prompt batches may be length-heterogeneous (attention-cache architectures):
prompts are right-padded to a shared bucket, next-token logits are gathered
at each request's true last position, and every downstream step masks the
cache at the per-request length.  Recurrent architectures (RWKV / Mamba
hybrids) carry state, so right-padding would contaminate it; batched
``generate`` keeps the homogeneous-length requirement for them, while the
``submit``/``step`` continuous-batching path prefills each request alone at
its exact length and so serves mixed lengths for every architecture.

``submit()``/``step()`` are the continuous-batching seam: requests are
admitted into free slots (gated on both a free slot *and* free pages) and
retired between decode steps while the rest of the batch keeps running.
The one-shot ``generate()`` path keeps the dense per-row cache — it admits
a whole batch at once and drops it at the end, so paging buys it nothing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .. import parallel
from ..core.reason import resolve_num_splits
from ..models import transformer
from ..models.config import ModelConfig
from .draft import NgramProposer


# Speculative-decode draft throttle: a request whose drafts keep getting
# rejected quarters its allowed draft length down to zero (its rows then
# ride the cheap plain-decode dispatch), and re-probes with a single draft
# once per this many steps so a continuation that turns repetitive later
# can re-earn its full draft budget.  The quarter-step decay and the long
# probe period are what bound the zero-acceptance overhead: a draft-hostile
# stream pays the wide verify window on ~2 + new_tokens/32 steps instead of
# every step.
_SPEC_PROBE_PERIOD = 32


def _bucket(n: int, lo: int = 64) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class PageAllocator:
    """Ref-counted free-list allocator over a fixed pool of KV-cache pages,
    with a content-addressed *prefix index* for shared-prefix reuse.

    Pages are the unit of HBM reservation: a request holds
    ``ceil(len / page_size)`` pages, so its reservation is O(true length)
    rather than O(max_len).  :meth:`alloc` is all-or-nothing — it returns
    ``None`` when the pool cannot satisfy the request, and the caller
    queues or preempts; a request is never given a partial allocation.

    **Refcounts** make pages shareable: :meth:`alloc` hands out pages at
    refcount 1, :meth:`ref` adds holders (a prefix-cache hit maps the same
    physical page into several block tables), and :meth:`free` only
    *decrements* — a page leaves circulation when its count hits zero.
    Freeing a page nobody holds raises (the double-free guard).

    **The prefix index** maps page-aligned token chunks to the pages that
    hold their KV.  Keys are content-addressed chains of *interned
    nodes*: chunk ``i`` of a prompt is identified by the node interned
    for ``(parent node of chunks 0..i-1, that chunk's token tuple)``, so
    reaching a node proves the chunk's tokens *and* its entire history
    are identical — exactly the guarantee the earlier literal
    ``tokens[: (i+1) * page_size]`` tuple keys gave, which (positions
    being equal) makes the cached KV entries bit-identical to what a
    recompute would produce.  Interning buys the asymptotics: each node
    stores one page-size chunk plus a parent id, so a cached L-token
    chain costs O(L) memory and O(L) hashing to walk, instead of the
    literal keys' O(L^2 / page_size) — and unlike vLLM-style rolling
    hashes there is still no collision exposure, because the intern table
    compares real token tuples on lookup.  Only *full* pages are indexed:
    a partial page's content still changes as its owner decodes.  An
    indexed page whose refcount drops to zero is not freed but parked in
    an LRU *evictable* set — its content stays valid (and matchable: the
    prefix-cache-hit-after-retire path) until :meth:`alloc` reclaims it
    under pressure, at which point it leaves the index (nodes whose
    subtree no longer indexes any page are pruned with it).

    Matching (:meth:`match_prefix`) walks full-chunk chain nodes, then
    extends at most one page further by *partial* match — a prompt that
    ends (or diverges) mid-way through a cached page maps that page too,
    masked at the matched length.  Writing into such a shared page is what
    triggers the engine's copy-on-write.
    """

    _ROOT = 0                                    # parent id of chunk 0 nodes

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0:
            raise ValueError(f"num_pages {num_pages} must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free = list(range(self.num_pages - 1, -1, -1))  # LIFO
        self._ref: dict[int, int] = {}           # page -> refcount (> 0)
        self._evictable: dict[int, None] = {}    # refcount-0 cached, LRU order
        # interned chain nodes: (parent node, chunk tokens) <-> node id.
        # A node exists while it indexes a page or any descendant does.
        self._intern: dict[tuple, int] = {}      # (parent, chunk) -> node
        self._node_key: dict[int, tuple] = {}    # node -> (parent, chunk)
        self._node_kids: dict[int, int] = {}     # node -> child-node count
        self._next_node = self._ROOT + 1
        self._index: dict[int, int] = {}         # node -> page
        self._page_key: dict[int, int] = {}      # page -> node
        self._page_tokens: dict[int, tuple] = {} # indexed page -> its chunk
        self._children: dict[int, set] = {}      # parent node -> indexed pages
        self.alloc_count = 0                     # pages ever handed out
        self.evictions = 0                       # cache entries reclaimed
        # int8-quantized pools: host mirror of the per-page absmax scale
        # rows (the device truth lives in the cache's "ks"/"vs"/"cs"
        # leaves).  Lifecycle follows page ownership — 0.0 while a page
        # is on the free list (a fresh page must never inherit a stale
        # scale), kept while evictable (prefix revival reuses content
        # *and* scale), copied on COW.  Unquantized engines simply leave
        # it all-zero.
        self.scale_table = np.zeros(self.num_pages, np.float32)

    @property
    def free_pages(self) -> int:
        """Allocatable pages: truly free plus cached-but-evictable."""
        return len(self._free) + len(self._evictable)

    @property
    def live_pages(self) -> int:
        """Pages some holder currently references (refcount > 0)."""
        return len(self._ref)

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages kept only for their prefix-cache content."""
        return len(self._evictable)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` cache entries."""
        return -(-int(tokens) // self.page_size)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def is_indexed(self, page: int) -> bool:
        return page in self._page_key

    def alloc(self, n: int) -> Optional[list[int]]:
        if n > self.free_pages:
            return None
        out = []
        for _ in range(n):
            if self._free:
                p = self._free.pop()
            else:
                # reclaim the least-recently-parked cache page; its prefix
                # entry dies with it (the content is about to be reused)
                p = next(iter(self._evictable))
                del self._evictable[p]
                self._unindex(p)
                self.evictions += 1
            self._ref[p] = 1
            self.scale_table[p] = 0.0   # fresh content, fresh scale
            out.append(p)
        self.alloc_count += n
        return out

    def ref(self, pages: list[int]) -> None:
        """Add a holder to already-live or cached pages (prefix-cache hit)."""
        for p in pages:
            if p in self._evictable:          # revive from the cache: 0 -> 1
                del self._evictable[p]
                self._ref[p] = 1
            elif self._ref.get(p, 0) > 0:
                self._ref[p] += 1
            else:
                raise ValueError(f"ref of free/invalid page {p}")

    def free(self, pages: list[int]) -> None:
        """Drop one holder per page; a page leaves circulation at zero —
        to the evictable cache if its content is prefix-indexed, else to
        the free list."""
        for p in pages:
            if self._ref.get(p, 0) <= 0:
                raise ValueError(f"double/invalid free of page {p}")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                if p in self._page_key:
                    self._evictable[p] = None     # most-recently parked
                else:
                    self._free.append(p)
                    self.scale_table[p] = 0.0

    # ---- prefix index -------------------------------------------------

    def _intern_node(self, parent: int, chunk: tuple) -> int:
        """Get-or-create the chain node for ``chunk`` under ``parent``.
        Interning makes chain identity a dict hit on (parent id, one
        page-size tuple) — O(page_size), not O(history)."""
        key = (parent, chunk)
        node = self._intern.get(key)
        if node is None:
            node = self._next_node
            self._next_node += 1
            self._intern[key] = node
            self._node_key[node] = key
            self._node_kids[node] = 0
            if parent != self._ROOT:
                self._node_kids[parent] += 1
        return node

    def _prune_node(self, node: int) -> None:
        """Drop ``node`` and any now-useless ancestors: a chain node lives
        only while it indexes a page or a descendant node exists."""
        while node != self._ROOT and self._node_kids.get(node) == 0 \
                and node not in self._index:
            parent, chunk = self._node_key.pop(node)
            del self._intern[(parent, chunk)]
            del self._node_kids[node]
            if parent == self._ROOT:
                break
            self._node_kids[parent] -= 1
            node = parent

    def match_prefix(self, tokens: list[int]) -> tuple[list[int], int]:
        """Longest cached prefix of ``tokens``: full-page chain hits plus
        at most one partial hit into the next cached page.  Returns
        ``(pages, matched_tokens)``; the caller must :meth:`ref` the pages
        it keeps (a match alone takes no ownership)."""
        ps = self.page_size
        pages: list[int] = []
        matched = 0
        node = self._ROOT
        while matched + ps <= len(tokens):
            child = self._intern.get(
                (node, tuple(tokens[matched:matched + ps])))
            if child is None or child not in self._index:
                # no such chain — or a hole: the chunk's node survives
                # through indexed descendants but its own page is gone
                break
            pages.append(self._index[child])
            matched += ps
            node = child
        tail = tuple(tokens[matched:])
        if tail:
            best, best_len = None, 0
            for p in self._children.get(node, ()):
                cached = self._page_tokens[p]
                r = 0
                for a, b in zip(tail, cached):
                    if a != b:
                        break
                    r += 1
                if r > best_len:
                    best, best_len = p, r
            if best is not None:
                pages.append(best)
                matched += best_len
        return pages, matched

    def register(self, tokens: list[int], pages: list[int],
                 start: int = 0, resume=None) -> tuple:
        """Index the *full* pages of ``tokens`` from chunk index ``start``
        on (``pages[i]`` holds chunk ``i``).  First writer wins —
        identical content arriving in a different page is not re-indexed —
        and re-registration is a no-op.  The chain is walked (and interned
        where new) from the root, one O(page_size) dict key per chunk, so
        registering an L-token chain costs O(L) hashing total, never
        O(L^2 / page_size).

        Returns a ``(chunks_covered, node)`` *resume handle*; a growing
        request passes the previous call's handle back so each page
        boundary re-hashes only the new chunk instead of re-walking the
        chain (a stale handle — its node pruned since — silently falls
        back to the full walk)."""
        ps = self.page_size
        n = min(len(tokens) // ps, len(pages))
        node, lo = self._ROOT, 0
        if resume is not None:
            k, rnode = resume
            if start <= k <= n and (rnode == self._ROOT
                                    or rnode in self._node_kids):
                node, lo = rnode, k
        for i in range(lo, n):
            chunk = tuple(tokens[i * ps:(i + 1) * ps])
            node = self._intern_node(node, chunk)
            if i < start:
                continue
            p = pages[i]
            if node in self._index or p in self._page_key:
                continue
            if self._ref.get(p, 0) <= 0:
                # leave no barren interned nodes behind the raise — a
                # rejected register must not poison check_invariants
                self._prune_node(node)
                raise ValueError(f"register of free/invalid page {p}")
            self._index[node] = p
            self._page_key[p] = node
            self._page_tokens[p] = chunk
            self._children.setdefault(self._node_key[node][0],
                                      set()).add(p)
        # nodes interned above that ended up indexing nothing (first-
        # writer-wins skips) must not leak: prune from the tail up
        self._prune_node(node)
        return (n, node)

    def _unindex(self, p: int) -> None:
        node = self._page_key.pop(p, None)
        if node is None:
            return
        del self._index[node]
        del self._page_tokens[p]
        parent = self._node_key[node][0]
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(p)
            if not kids:
                del self._children[parent]
        self._prune_node(node)

    def unindex(self, p: int) -> None:
        """Forget a page's prefix-cache entry (callers must do this before
        mutating a sole-owner indexed page — the content diverges from the
        key).  An evictable page loses its only reason to stay cached and
        returns to the free list."""
        self._unindex(p)
        if p in self._evictable:
            del self._evictable[p]
            self._free.append(p)
            self.scale_table[p] = 0.0

    def set_scale(self, pages, values) -> None:
        """Record the (grown) absmax scales of freshly written pages —
        the engine mirrors its device-side scale rows here so the
        invariant checker can see page/scale lifecycle agreement."""
        self.scale_table[np.asarray(pages, np.int64)] = \
            np.asarray(values, np.float32)

    def copy_scale(self, src: int, dst: int) -> None:
        """COW bookkeeping: the fork duplicates page *content*, so the
        copy dequantizes with the source page's scale."""
        self.scale_table[dst] = self.scale_table[src]

    def check_invariants(self) -> None:
        """Conservation + consistency (the property-test oracle): every
        page is exactly one of free / evictable / live; refcounts are
        positive; the index maps and the interned chain-node store are
        mutually consistent, and no chain node leaks (every leaf indexes
        a page)."""
        free, evict, live = set(self._free), set(self._evictable), \
            set(self._ref)
        assert len(self._free) == len(free), "free list duplicates"
        assert not (free & evict) and not (free & live) \
            and not (evict & live), "page in two states"
        assert len(free) + len(evict) + len(live) == self.num_pages, \
            f"page leak: {len(free)}+{len(evict)}+{len(live)} " \
            f"!= {self.num_pages}"
        assert all(v > 0 for v in self._ref.values()), "refcount <= 0 held"
        assert set(self._index.values()) == set(self._page_key), \
            "index/page_key mismatch"
        assert all(self._index[n] == p for p, n in self._page_key.items())
        assert set(self._page_tokens) == set(self._page_key)
        kids = {p for s in self._children.values() for p in s}
        assert kids == set(self._page_key), "children set drift"
        assert evict <= set(self._page_key), "evictable page not indexed"
        # a page on the free list has no content contract left, so it
        # must not still be matchable through the prefix index — the
        # speculative-decode rollback path frees draft pages wholesale,
        # and an indexed page slipping through would serve a future
        # prefix hit from reused (overwritten) storage
        assert not (free & set(self._page_key)), \
            "indexed page on the free list"
        # quantized pools: a free page's scale row must be zero — a
        # rolled-back or freed page re-entering circulation with a stale
        # scale would dequantize its next owner's int8 content wrongly
        # (live and evictable pages keep theirs: prefix revival reuses
        # content + scale together)
        assert not any(self.scale_table[p] for p in free), \
            "free page holds a stale scale row"
        # interned chain nodes: the two maps mirror; every indexing node
        # exists and holds a full chunk; recorded child counts match; a
        # node with neither an index entry nor descendants is a leak
        assert {v: k for k, v in self._intern.items()} == self._node_key, \
            "intern/node_key mismatch"
        assert all(n in self._node_key and
                   len(self._node_key[n][1]) == self.page_size
                   for n in self._index), "index node drift"
        assert all(self._page_tokens[p] == self._node_key[n][1]
                   for p, n in self._page_key.items()), "chunk drift"
        counts: dict[int, int] = {}
        for parent, _ in self._node_key.values():
            if parent != self._ROOT:
                counts[parent] = counts.get(parent, 0) + 1
        assert all(self._node_kids[n] == counts.get(n, 0)
                   for n in self._node_key), "child-count drift"
        assert set(self._node_kids) == set(self._node_key)
        assert all(self._node_kids[n] > 0 or n in self._index
                   for n in self._node_key), "leaked chain node"
        assert all(parent == self._ROOT or parent in self._node_key
                   for parent, _ in self._node_key.values()), \
            "dangling parent pointer"


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray          # (B, new)
    prompt_len: list[int]
    steps: int


@dataclasses.dataclass
class Request:
    """One serving request moving through the continuous-batching loop.

    ``priority`` orders both admission and preemption (higher = more
    important; ties broken FIFO).  ``pf_pos``/``pf_end`` track budgeted
    chunked prefill: the request holds a slot and pages but its prompt is
    only computed up to ``pf_pos`` (< ``pf_end``); -1 means whole-prompt
    admission.  The ``submit/first_token/finish`` stamps are recorded in
    both wall-clock seconds (``*_time``) and engine step counts
    (``*_step``) — the step counts are deterministic, so benchmarks can
    assert on them."""

    uid: int
    prompt: list[int]
    max_new_tokens: int
    temperature: float = 0.0
    priority: int = 0
    tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    seq: int = -1               # admission order (preemption picks max)
    pf_pos: int = -1            # budgeted prefill: next position to compute
    pf_end: int = -1            # budgeted prefill: context length
    spec_k: int = -1            # speculative draft throttle (-1 = full k)
    preempted: bool = False     # requeued victim (goes ahead of fresh)
    submit_time: float = 0.0
    submit_step: int = 0
    first_token_time: Optional[float] = None
    first_token_step: Optional[int] = None
    finish_time: Optional[float] = None
    finish_step: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens

    @property
    def prefilling(self) -> bool:
        """Holds a slot but its prompt is not fully computed yet (budgeted
        chunked prefill in flight)."""
        return self.slot >= 0 and 0 <= self.pf_pos < self.pf_end


class ServeEngine:
    """Mesh-agnostic serving engine (pass ``shardings`` upstream via params).

    Compile accounting: ``prefill_compiles`` / ``decode_compiles`` count jit
    traces of the two step functions — the load-bearing guarantees are that
    ``decode_compiles`` stays ≤ the number of distinct length buckets
    touched (independent of how many tokens are generated), and
    ``prefill_compiles`` on the submit/step path stays ≤ the number of
    distinct *prompt buckets* touched (independent of how many distinct
    prompt lengths arrive).  Architectures where right-padding perturbs
    numerics — recurrent state, capacity-truncated MoE routing — prefill
    at the exact length and trace per distinct length instead.

    Paging: ``paged=True`` (the default for attention-cache architectures)
    stores the submit/step KV cache as page pools managed by a
    :class:`PageAllocator` — see the module docstring.  ``page_size`` must
    be a power of two ≤ the decode buckets and divide ``max_len``
    (validated when submit/step first materialise the pools — the dense
    ``generate()`` path has no such constraints); ``num_pages`` defaults to
    dense-capacity parity (``max_batch * max_len / page_size`` + the
    reserved dump page) — pass fewer to bound KV HBM below the dense
    reservation, at the cost of queueing/preemption under pressure.
    Architectures with no attention cache (pure RWKV/Mamba state) have
    nothing to page; ``paged`` silently turns off there.

    Split-KV decode: every decode dispatch carries a *static* split count
    (Flash-Decoding work partitioning) chosen by the reasoning heuristic
    over this dispatch's (batch x KV heads) launch width and length
    bucket — or forced via ``num_splits`` (1 disables splitting; used by
    benchmarks for A/B).  The count is part of the decode jit cache key
    along with the bucket, the batch, and paged-ness, and the engine
    asserts ``decode_compiles == len(distinct keys)`` after every decode,
    so a reasoned split change can never silently retrace.

    Speculative decode: ``spec_decode=True`` swaps the decode dispatch
    for draft -> verify -> rollback.  A draft source (``draft_proposer``;
    default: self-speculative n-gram prompt-lookup, see
    :mod:`repro.serve.draft`) proposes up to ``draft_k`` continuation
    tokens per greedy request per step; one batched ``verify`` dispatch —
    the TL verify mode: a K+1-token causal window at the row's runtime
    history length, chunk-prefill tiling with decode's split-KV
    partitioning — scores every position at once, the longest
    draft prefix agreeing with the verify argmaxes commits, and pages
    allocated past the accepted length roll back to the pool through the
    allocator's refcount machinery.  The committed stream is
    token-for-token identical to non-speculative greedy decode; the jit
    cache is keyed ``(batch, draft capacity, bucket, splits, paged)``
    with the same no-silent-retrace assertion as decode.  The path needs
    the paged cache and pad-safe numerics (recurrent state cannot roll
    back; capacity-truncated MoE couples drafts into committed tokens),
    elsewhere the flag silently turns off; temperature > 0 requests ride
    the verify dispatch undrafted (plain decode semantics).

    Prefix cache: ``prefix_cache=True`` (the default) lets paged
    admission reuse cached pages for page-aligned prompt prefixes (plus
    one partial page at the divergence point, copy-on-write protected).
    It silently turns off where reuse would change numerics: recurrent
    architectures (state must integrate every token; pages only cache
    attention KV) and capacity-truncated MoE (routing couples every token
    in a dispatch).  ``prefill_chunk`` (a page multiple; default
    ``4 * page_size``) sets the chunked-prefill granularity — MoE
    architectures prefill the whole prompt as a single exact-length chunk
    for the same routing reason, still directly into pages.

    Scheduler: ``prefill_budget`` (prompt tokens per step; None = off)
    turns whole-prompt admission into budgeted chunked interleaving —
    see the module docstring and :meth:`_schedule_prefill`.  The budget
    is a soft cap: a chunk is indivisible, so the last dispatch of a step
    may overshoot by less than one chunk, and a budget below one page
    still schedules one minimal chunk per step (progress is guaranteed).
    Requests carry a ``priority`` (:meth:`submit`): admission order is
    priority-then-FIFO, budgeted prefill spends its tokens on the highest
    priority first, and preemption victims are picked lowest-priority-
    then-youngest.  Interleaving needs pad-safe paged prefill, so
    recurrent and MoE architectures (and dense engines) fall back to
    whole-prompt admission; priorities and metrics still apply.
    :meth:`stats` snapshots engine-tracked TTFT/TPOT percentiles.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 2048, vision_embeds=None,
                 decode_bucket_lo: int = 64, prompt_bucket_lo: int = 16,
                 paged: bool = True, page_size: int = 64,
                 num_pages: Optional[int] = None,
                 prefix_cache: bool = True,
                 prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 num_splits: Optional[int] = None,
                 spec_decode: bool = False, draft_k: int = 4,
                 draft_proposer=None,
                 kv_quant: bool = False,
                 target: str = "v5e",
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.vision = vision_embeds
        self.decode_bucket_lo = decode_bucket_lo
        self.prompt_bucket_lo = prompt_bucket_lo
        # recurrent state (RWKV / Mamba hybrid) cannot be right-padded
        self.recurrent = bool(getattr(cfg, "rwkv", False)
                              or getattr(cfg, "hybrid_period", 0))
        # right-padding is numerics-preserving only when every layer is
        # per-token: recurrent state integrates the pad tokens, and
        # capacity-truncated MoE routing lets pad tokens displace real ones
        # from expert buffers — both prefill at the exact length instead
        # (one trace per distinct prompt length, documented trade-off)
        self._pad_safe_prefill = not (self.recurrent
                                      or bool(getattr(cfg, "moe", False)))
        kinds, _ = transformer.period_spec(cfg)
        has_attn_cache = any(k in ("attn", "self") for k in kinds) or (
            bool(cfg.first_k_dense) and not getattr(cfg, "rwkv", False))
        self.paged = bool(paged and has_attn_cache)
        self.page_size = int(page_size)
        # Prefix reuse is sound only for per-token architectures: a
        # recurrent layer's state must integrate every prompt token (pages
        # cache attention KV, not Mamba/RWKV state), and capacity-truncated
        # MoE routing couples every token in a dispatch, so skipping the
        # prefix would change the suffix's numerics.
        self.prefix_cache = bool(prefix_cache and self.paged
                                 and self._pad_safe_prefill)
        # Chunked-prefill granularity (page multiple).  MoE architectures
        # prefill the whole prompt as one exact-length chunk — splitting a
        # routing batch perturbs capacity truncation — but still write
        # straight into pages (no dense-then-scatter copy).
        self.prefill_chunk = None if prefill_chunk is None \
            else int(prefill_chunk)
        # Budgeted chunked-prefill interleaving (None = whole-prompt
        # admission).  Chunks are page-aligned, so the effective per-step
        # spend rounds to page multiples; see the class docstring.
        if prefill_budget is not None and int(prefill_budget) <= 0:
            raise ValueError(f"prefill_budget {prefill_budget} must be a "
                             "positive token count (or None to disable "
                             "chunked interleaving)")
        self.prefill_budget = None if prefill_budget is None \
            else int(prefill_budget)
        # layout constraints are checked at first *paged* use (submit/step
        # materialise the pools) so generate()-only engines — which keep
        # the dense per-row cache — accept any max_len, as before
        self.num_pages = None if num_pages is None else int(num_pages)
        # split-KV decode: None = reason chooses per dispatch; an int
        # forces that count (1 = sequential KV pass, the A/B baseline).
        # ``target`` is the device the split heuristic reasons about
        # (decode_parallelism differs across TPU generations).
        self.num_splits = None if num_splits is None else int(num_splits)
        self.target = target
        # Tensor-parallel serving mesh (None = single device).  Heads — or,
        # for MLA, the per-rank page-table column range — shard over the
        # mesh's ``model`` axis per :func:`parallel.choose_serve_plan`;
        # every dispatch on the hot path (decode / chunk prefill / verify)
        # runs inside shard_map while the host-side scheduler (allocator,
        # block tables, scale mirrors, prefix index) stays replicated and
        # byte-identical to the single-device engine.
        self.mesh = mesh
        if mesh is not None:
            axes = tuple(getattr(mesh, "axis_names", ()))
            if "model" not in axes:
                raise ValueError(
                    f"serving mesh needs a 'model' axis (got {axes}); "
                    "build one with launch.make_host_mesh or "
                    "jax.make_mesh((data, model), ('data', 'model'))")
            if not self.paged:
                raise ValueError(
                    "mesh serving is paged-only (the sharded dispatches "
                    "run over page pools); construct with paged=True on "
                    "an attention-cache architecture")
            self._tp = parallel.choose_serve_plan(
                cfg, int(mesh.shape["model"]))
            self._mesh_key = tuple(int(mesh.shape[a]) for a in axes)
            if self._tp.plan == "seq":
                unit = self.page_size * self._tp.size
                if self.max_len % unit:
                    raise ValueError(
                        "the MLA seq plan splits page-table columns "
                        f"evenly across ranks: max_len {self.max_len} "
                        "must be a multiple of page_size * model_axis "
                        f"({unit})")
            if self._tp.plan == "q" and self._tp.size > 1:
                # group-interleaved head order (host-side, once) so each
                # rank's contiguous q slice still reshapes into GQA groups
                params = parallel.permute_q_heads(params, cfg,
                                                  self._tp.size)
            pspec = jax.tree_util.tree_map_with_path(
                lambda pth, leaf: parallel.serve_param_pspec(
                    pth, leaf, self._tp), params)
            params = jax.device_put(params, jax.tree.map(
                lambda s: NamedSharding(mesh, s), pspec,
                is_leaf=lambda x: isinstance(x, P)))
            self.params = params
        else:
            self._tp = None
            self._mesh_key = None
        # Int8-quantized KV pages: pools store symmetric int8 with one
        # f32 absmax scale per page ("ks"/"vs"/"cs" cache leaves); the
        # attention layer quantizes on scatter and dequantizes per page
        # inside the kernel KV loop, so the same pool HBM holds ~2x the
        # tokens (bf16) at a bounded dequant error.  A paged-cache-only
        # contract (the scale table rides the block table) — like
        # prefix_cache, the flag silently turns off on dense engines.
        self.kv_quant = bool(kv_quant and self.paged)
        # Speculative decoding: a draft source proposes up to ``draft_k``
        # continuation tokens per request per step and one batched
        # ``verify`` dispatch (TL mode="verify") scores them all; the
        # longest agreeing prefix commits, pages past the accepted length
        # roll back to the pool.  Verify is a paged chunk program, so the
        # path needs the paged cache and pad-safe numerics (a recurrent
        # state cannot be rolled back; capacity-truncated MoE routing
        # couples draft tokens into the committed ones' numerics) —
        # elsewhere the flag silently turns off, like prefix_cache.
        if int(draft_k) < 1:
            raise ValueError(f"draft_k {draft_k} must be >= 1")
        self.spec_decode = bool(spec_decode and self.paged
                                and self._pad_safe_prefill)
        self.draft_k = int(draft_k)
        self._proposer = draft_proposer if draft_proposer is not None \
            else NgramProposer()
        self._decode_keys: set = set()
        self._verify_keys: set = set()
        self.prefill_compiles = 0
        self.decode_compiles = 0
        self.verify_compiles = 0
        # speculative-decode observability: drafts offered vs accepted
        # (the per-dispatch acceptance-rate samples feed stats()'s
        # p50/p99) and pages the rollback returned to the pool
        self.drafted_tokens = 0       # draft tokens sent to verify
        self.accepted_tokens = 0      # drafts committed (excl. t0)
        self.rollback_pages = 0       # spec pages freed past acceptance
        self._accept_rates: list[float] = []
        # serving-observability counters (prefix cache + COW)
        self.prefix_lookups = 0       # submit/step admissions that probed
        self.prefix_hits = 0          # admissions that reused >= 1 token
        self.prefix_hit_tokens = 0    # prompt tokens served from the cache
        self.prefill_tokens = 0       # prompt tokens actually computed
        self.cow_count = 0            # copy-on-write page copies
        self.preemptions = 0          # active requests evicted to the queue
        self.inflight_dedup_pages = 0  # pages adopted from in-flight peers
        # engine-tracked latency samples (see stats()): TTFT is submit ->
        # first sampled token, TPOT the mean inter-token gap of a finished
        # request; each in wall seconds and in deterministic step counts
        self._step_idx = 0
        self._ttft_s: list[float] = []
        self._ttft_steps: list[int] = []
        self._tpot_s: list[float] = []
        self._tpot_steps: list[float] = []
        self._n_finished = 0
        self._n_generated = 0

        def prefill(params, tokens, caches):
            self.prefill_compiles += 1          # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, tokens, cfg, caches=caches, cache_len=0,
                vision_embeds=self.vision)
            return logits, caches

        # cache_len is runtime data (a per-request vector); only the length
        # bucket — how many cache entries attention reads — and the split
        # count are static, so generating T tokens costs at most
        # O(log2 max_len) decode traces per split regime.
        # ``tables`` is the paged path's block-table operand (None = dense).
        def decode(params, tok, caches, cache_len, tables, kv_bucket,
                   num_splits):
            self.decode_compiles += 1           # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, tok, cfg, caches=caches, cache_len=cache_len,
                kv_bucket=kv_bucket, num_splits=num_splits,
                block_tables=tables,
                page_size=self.page_size if tables is not None else None,
                vision_embeds=self.vision, tp=self._tp)
            return logits[:, -1], caches

        # one chunk of chunked prefill, written straight into the pages:
        # compiled per (chunk capacity, kv bucket) — never per chunk
        # position or prompt length (cache_len and chunk_valid are runtime
        # vectors; chunk_valid masks a padded tail's scatter so the pad
        # positions never land in pages that may already be shared)
        def chunk_prefill(params, tokens, caches, cache_len, tables,
                          chunk_valid, kv_bucket):
            self.prefill_compiles += 1      # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, tokens, cfg, caches=caches, cache_len=cache_len,
                kv_bucket=kv_bucket, block_tables=tables,
                page_size=self.page_size, chunk_valid=chunk_valid,
                tp=self._tp)
            return logits, caches

        # speculative verify: one K+1-token causal window per row (the
        # committed token plus the drafts) through the TL verify mode —
        # chunk-prefill geometry with decode's split-KV partitioning.
        # cache_len (per-row history) and chunk_valid (per-row real draft
        # count) are runtime vectors; only the draft capacity (the token
        # axis), the bucket, and the split count are static, so the jit
        # cache is keyed exactly like decode plus the capacity.
        def verify(params, toks, caches, cache_len, tables, chunk_valid,
                   kv_bucket, num_splits):
            self.verify_compiles += 1       # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, toks, cfg, caches=caches, cache_len=cache_len,
                kv_bucket=kv_bucket, num_splits=num_splits,
                block_tables=tables, page_size=self.page_size,
                chunk_valid=chunk_valid, verify=True, tp=self._tp)
            return logits, caches

        # copy one pool page (COW): page ``src`` -> ``dst`` in every
        # attention pool leaf; src/dst are runtime scalars so every COW
        # event reuses one trace
        def cow_copy(caches, src, dst):
            def copy_page(axis, leaf):
                sl = (slice(None),) * axis
                return leaf.at[sl + (dst,)].set(leaf[sl + (src,)])

            return self._map_paged_caches(copy_page,
                                          lambda axis, leaf: leaf, caches)

        # zero one page's per-page scale rows (int8-quantized pools only;
        # the (…, P) scale leaves are the attn leaves indexed *directly*
        # by page): called when the allocator re-circulates a page, so
        # running-max quantization starts fresh instead of inheriting the
        # previous owner's absmax
        def zero_scale(caches, page):
            def z(axis, leaf):
                if leaf.ndim == axis + 1:
                    sl = (slice(None),) * axis
                    return leaf.at[sl + (page,)].set(0.0)
                return leaf

            return self._map_paged_caches(z, lambda axis, leaf: leaf,
                                          caches)

        self._prefill = jax.jit(prefill)
        if mesh is None:
            self._decode = jax.jit(
                decode, static_argnames=("kv_bucket", "num_splits"))
            self._chunk_step = jax.jit(chunk_prefill,
                                       static_argnames=("kv_bucket",))
            self._verify = jax.jit(
                verify, static_argnames=("kv_bucket", "num_splits"))
        else:
            shard_map = getattr(jax, "shard_map", None)
            if shard_map is None:  # pragma: no cover - version fallback
                from jax.experimental.shard_map import shard_map
            tp = self._tp

            # shard_map wrapper for one hot-path dispatch: params and
            # cache leaves shard per the serve plan, every other operand
            # (tokens, lens, tables, chunk_valid) is replicated, and the
            # logits come back replicated — the attention/FFN psums (and
            # the seq plan's LSE merge) make every rank's output the full
            # result, so downstream sampling is rank-independent.
            def _sharded(fn, n_rep):
                def call(params, toks, caches, *rest, **static):
                    pspec = jax.tree_util.tree_map_with_path(
                        lambda pth, leaf: parallel.serve_param_pspec(
                            pth, leaf, tp), params)
                    cspec = jax.tree_util.tree_map_with_path(
                        lambda pth, leaf: parallel.serve_cache_pspec(
                            pth, leaf, tp), caches)

                    def local(p, t, c, *r):
                        return fn(p, t, c, *r, **static)

                    kwargs = dict(
                        mesh=mesh,
                        in_specs=(pspec, P(), cspec) + (P(),) * n_rep,
                        out_specs=(P(), cspec))
                    try:
                        mapped = shard_map(local, check_vma=False,
                                           **kwargs)
                    except TypeError:  # pragma: no cover - older spelling
                        mapped = shard_map(local, check_rep=False,
                                           **kwargs)
                    return mapped(params, toks, caches, *rest)
                return call

            dec = _sharded(decode, 2)

            def decode_sharded(params, tok, caches, cache_len, tables,
                               kv_bucket, num_splits):
                return dec(params, tok, caches, cache_len, tables,
                           kv_bucket=kv_bucket, num_splits=num_splits)

            chk = _sharded(chunk_prefill, 3)

            def chunk_sharded(params, tokens, caches, cache_len, tables,
                              chunk_valid, kv_bucket):
                return chk(params, tokens, caches, cache_len, tables,
                           chunk_valid, kv_bucket=kv_bucket)

            ver = _sharded(verify, 3)

            def verify_sharded(params, toks, caches, cache_len, tables,
                               chunk_valid, kv_bucket, num_splits):
                return ver(params, toks, caches, cache_len, tables,
                           chunk_valid, kv_bucket=kv_bucket,
                           num_splits=num_splits)

            self._decode = jax.jit(
                decode_sharded,
                static_argnames=("kv_bucket", "num_splits"))
            self._chunk_step = jax.jit(chunk_sharded,
                                       static_argnames=("kv_bucket",))
            self._verify = jax.jit(
                verify_sharded,
                static_argnames=("kv_bucket", "num_splits"))
        self._cow_copy = jax.jit(cow_copy)
        self._zero_scale = jax.jit(zero_scale)

        # continuous-batching state (submit/step API)
        self._queue: list[Request] = []
        self._active: list[Optional[Request]] = []
        self._slot_caches = None
        self._slot_logits = None
        self._slot_lens: Optional[np.ndarray] = None
        self._allocator: Optional[PageAllocator] = None
        self._slot_tables: Optional[np.ndarray] = None
        self._slot_pages: list[list[int]] = []
        self._slot_nodes: list = []
        self._dump_page = 0
        self._next_uid = 0
        self._admit_seq = 0
        self._finished_early: list[Request] = []
        self._key = jax.random.PRNGKey(0)

    # ------------------------------------------------------------------
    # shared helpers
    # ------------------------------------------------------------------

    def _decode_bucket(self, needed: int) -> int:
        """Smallest power-of-two bucket covering ``needed`` cache entries
        (paged engines never go below one page)."""
        if needed > self.max_len:
            raise ValueError(f"cache length {needed} exceeds max_len "
                             f"{self.max_len}")
        lo = self.decode_bucket_lo
        if self.paged:
            lo = max(lo, self.page_size)
            if self._tp is not None and self._tp.plan == "seq":
                # each rank owns an equal page-table column range, so the
                # page count (bucket / page_size) must divide by the axis;
                # both are powers of two, so flooring the bucket suffices
                lo = max(lo, self.page_size * self._tp.size)
        return min(_bucket(needed, lo), self.max_len)

    def _decode_splits(self, bucket: int, batch: int,
                       paged_dispatch: bool,
                       mode: str = "decode") -> int:
        """Static split-KV count for a decode/verify dispatch: the forced
        engine override, or the reasoning heuristic over this dispatch's
        launch width (``batch * KV heads``; one latent head for MLA),
        bucket, and layout (``generate()`` decodes densely even on a
        paged engine).  Deterministic, so it doubles as part of the
        decode jit key.  Verify dispatches score splits through the same
        autotuner search (``mode="verify"``)."""
        rows = batch * (1 if getattr(self.cfg, "mla", False)
                        else self.cfg.num_kv_heads)
        shards, kv_len = 1, bucket
        if self._tp is not None and self._tp.size > 1:
            if self._tp.plan == "kv":
                # each rank launches rows/size kernel rows (its head slice)
                shards = self._tp.size
            elif self._tp.plan == "seq":
                # rows stay whole; each rank scans bucket/size KV entries
                kv_len = max(self.page_size, bucket // self._tp.size)
            # 'q' plan: KV heads replicated — the local launch width is
            # unchanged, so the single-device reasoning already applies
        return resolve_num_splits(
            self.num_splits, rows=rows, kv_len=kv_len, mode=mode,
            page_size=self.page_size if paged_dispatch else None,
            target=self.target, shards=shards)

    def _run_decode(self, toks, caches, lens, tables, bucket: int):
        """One decode jit dispatch, with every shape-relevant knob —
        batch, bucket, split count, paged-ness — recorded as the cache
        key; the compile counter must track the distinct keys exactly
        (anything else is a silent retrace, the bug class this guards)."""
        splits = self._decode_splits(bucket, int(toks.shape[0]),
                                     tables is not None)
        self._decode_keys.add(
            (int(toks.shape[0]), bucket, splits, tables is not None,
             self._mesh_key))
        out = self._decode(self.params, toks, caches, lens, tables,
                           kv_bucket=bucket, num_splits=splits)
        assert self.decode_compiles == len(self._decode_keys), \
            f"decode retraced outside its key set: {self.decode_compiles} " \
            f"compiles for {len(self._decode_keys)} distinct " \
            f"(batch, bucket, splits, paged, mesh-shape) keys"
        return out

    def _run_verify(self, toks, caches, lens, tables, valid, bucket: int):
        """One speculative-verify jit dispatch with the same no-silent-
        retrace contract as :meth:`_run_decode`: the key adds the static
        draft capacity (the token axis) to (batch, bucket, splits,
        paged), and the compile counter must track the distinct keys
        exactly."""
        cap = int(toks.shape[1])
        splits = self._decode_splits(bucket, int(toks.shape[0]), True,
                                     mode="verify")
        self._verify_keys.add((int(toks.shape[0]), cap, bucket, splits,
                               True, self._mesh_key))
        out = self._verify(self.params, toks, caches, lens, tables, valid,
                           kv_bucket=bucket, num_splits=splits)
        assert self.verify_compiles == len(self._verify_keys), \
            f"verify retraced outside its key set: " \
            f"{self.verify_compiles} compiles for " \
            f"{len(self._verify_keys)} distinct " \
            f"(batch, cap, bucket, splits, paged, mesh-shape) keys"
        return out

    def _sample(self, logits, temperature: float, key):
        """Returns (tokens, next_key).  The key is threaded explicitly so
        batched ``generate`` and the submit/step API keep independent
        sampling streams."""
        if temperature > 0.0:
            key, k2 = jax.random.split(key)
            return jax.random.categorical(k2, logits / temperature,
                                          axis=-1), key
        return jnp.argmax(logits, axis=-1), key

    # ------------------------------------------------------------------
    # batch generate (one-shot; heterogeneous prompt lengths allowed)
    # ------------------------------------------------------------------

    def generate(self, prompts: list[list[int]], *, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0) -> GenResult:
        """Greedy/temperature generation for a batch of prompts.

        Prompt lengths may differ (attention-cache architectures): the batch
        is right-padded to a shared bucket, per-request last-position logits
        seed decoding, and each request's cache length is tracked
        separately.  Recurrent architectures require homogeneous lengths
        here — use :meth:`submit`/:meth:`step` for mixed lengths there.
        This one-shot path keeps the dense per-row cache (see module
        docstring); the paged storage belongs to the submit/step loop.
        """
        if self.mesh is not None:
            raise ValueError(
                "generate() keeps a dense per-row cache; the mesh engine "
                "serves through the paged submit()/step() path only")
        if len(prompts) > self.max_batch:
            raise ValueError(f"batch {len(prompts)} > max_batch "
                             f"{self.max_batch}")
        b = len(prompts)
        lens = [len(p) for p in prompts]
        if max(lens) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({max(lens)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len {self.max_len}; raise max_len or shorten "
                "the request (step() truncates at capacity instead)")
        if self.recurrent and len(set(lens)) != 1:
            raise ValueError(
                "recurrent architectures carry state, so right-padded "
                "heterogeneous prefill would contaminate it; group "
                f"requests by prompt length (got {sorted(set(lens))})")
        # homogeneous batches prefill at the exact length (recurrent-safe
        # and numerically identical to a manual decode); heterogeneous
        # batches right-pad to a shared bucket and mask per request
        pad_to = lens[0] if len(set(lens)) == 1 else \
            min(_bucket(max(lens), self.prompt_bucket_lo), self.max_len)
        toks = np.zeros((b, pad_to), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p

        caches = transformer.init_caches(self.cfg, b, self.max_len)
        logits, caches = self._prefill(self.params, jnp.asarray(toks), caches)
        # next-token logits come from each prompt's true last position
        last = jnp.asarray([l - 1 for l in lens])
        step_logits = logits[jnp.arange(b), last]

        key = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new_tokens), np.int32)
        lens_v = np.asarray(lens, np.int32)
        for t in range(max_new_tokens):
            tok, key = self._sample(step_logits, temperature, key)
            out[:, t] = np.asarray(tok)
            bucket = self._decode_bucket(int(lens_v.max()) + 1)
            step_logits, caches = self._run_decode(
                tok[:, None].astype(jnp.int32), caches,
                jnp.asarray(lens_v), None, bucket)
            lens_v = lens_v + 1
        return GenResult(tokens=out, prompt_len=lens, steps=max_new_tokens)

    # ------------------------------------------------------------------
    # continuous batching: submit / step
    # ------------------------------------------------------------------

    def submit(self, prompt: list[int], *, max_new_tokens: int = 16,
               temperature: float = 0.0, priority: int = 0) -> int:
        """Queue a request; it is admitted at the next :meth:`step`.

        ``priority`` (default 0; higher = more important) orders the
        queue: admission, budgeted prefill spend, and preemption-victim
        selection all prefer higher classes, FIFO within a class."""
        if self.vision is not None:
            raise ValueError(
                "submit()/step() admit requests one at a time, but "
                "vision_embeds are bound to the whole batch — use "
                "generate() for vision engines")
        if not prompt:
            raise ValueError("empty prompt: nothing to prefill")
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(prompt)}) leaves no room to "
                             f"decode within max_len {self.max_len}")
        if self.paged:
            need = self._page_allocator().pages_for(len(prompt))
            if need > self._page_allocator().num_pages - 1:
                raise ValueError(
                    f"prompt needs {need} pages but the pool only has "
                    f"{self._page_allocator().num_pages - 1} allocatable "
                    "pages; raise num_pages")
        req = Request(uid=self._next_uid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      priority=int(priority))
        req.submit_time = time.perf_counter()
        req.submit_step = self._step_idx
        self._next_uid += 1
        self._queue_insert(req)
        return req.uid

    @staticmethod
    def _queue_key(req: Request) -> tuple:
        """Total queue order: higher priority first; within a class,
        preemption victims go ahead of fresh arrivals (they already hold
        sampled tokens) in their original admission (``seq``) order, and
        fresh arrivals stay FIFO by ``uid``."""
        return (-req.priority, 0 if req.preempted else 1,
                req.seq if req.preempted else req.uid)

    def _queue_insert(self, req: Request) -> None:
        """Keep ``_queue`` sorted by :meth:`_queue_key`.  This is what
        makes multi-victim preemption order-preserving: the old
        insert-at-front requeue re-admitted the *latest* victim first
        whenever an earlier victim was still waiting, starving the oldest."""
        key = self._queue_key(req)
        i = 0
        while i < len(self._queue) \
                and self._queue_key(self._queue[i]) <= key:
            i += 1
        self._queue.insert(i, req)

    @property
    def active_requests(self) -> list[Request]:
        return [r for r in self._active if r is not None]

    @property
    def allocator(self) -> Optional[PageAllocator]:
        """The page allocator (None until first step / for dense engines)."""
        return self._allocator

    def _page_allocator(self) -> PageAllocator:
        self._ensure_slots()
        return self._allocator

    def _ensure_slots(self):
        if self._slot_caches is None:
            if self.paged:
                if self.page_size & (self.page_size - 1):
                    raise ValueError(
                        f"page_size {self.page_size} must be a power of "
                        "two (decode buckets are powers of two)")
                if self.max_len % self.page_size:
                    raise ValueError(
                        f"max_len {self.max_len} must be a multiple of "
                        f"page_size {self.page_size} for the paged "
                        "submit/step path (generate() has no such "
                        "constraint)")
                if self.num_pages is None:
                    # dense-capacity parity + the reserved dump page
                    self.num_pages = self.max_batch * \
                        (self.max_len // self.page_size) + 1
                if self.prefill_chunk is None:
                    self.prefill_chunk = min(4 * self.page_size,
                                             self.max_len)
                if self.prefill_chunk <= 0 \
                        or self.prefill_chunk % self.page_size:
                    raise ValueError(
                        f"prefill_chunk {self.prefill_chunk} must be a "
                        f"positive multiple of page_size {self.page_size} "
                        "(chunks are written page-aligned)")
            self._active = [None] * self.max_batch
            self._slot_caches = transformer.init_caches(
                self.cfg, self.max_batch, self.max_len, paged=self.paged,
                page_size=self.page_size,
                num_pages=self.num_pages if self.paged else None,
                kv_quant=self.kv_quant)
            if self.mesh is not None:
                # place pools on the mesh up front ('kv' plan: head-axis
                # slices per rank; everything else replicated) so the
                # first dispatch doesn't pay a layout-change transfer
                cspec = jax.tree_util.tree_map_with_path(
                    lambda pth, leaf: parallel.serve_cache_pspec(
                        pth, leaf, self._tp), self._slot_caches)
                self._slot_caches = jax.device_put(
                    self._slot_caches, jax.tree.map(
                        lambda s: NamedSharding(self.mesh, s), cspec,
                        is_leaf=lambda x: isinstance(x, P)))
            self._slot_lens = np.zeros((self.max_batch,), np.int32)
            vocab = self.cfg.vocab_size
            self._slot_logits = jnp.zeros((self.max_batch, vocab),
                                          jnp.float32)
            if self.paged:
                self._allocator = PageAllocator(self.num_pages,
                                                self.page_size)
                # reserved dump page: idle slot rows' table entries point
                # here, so their ride-along decode writes can never land in
                # a live request's pages
                self._dump_page = self._allocator.alloc(1)[0]
                self._slot_tables = np.full(
                    (self.max_batch, self.max_len // self.page_size),
                    self._dump_page, np.int32)
                self._slot_pages = [[] for _ in range(self.max_batch)]
                # per-slot prefix-index resume handles (see register():
                # each page boundary re-hashes one chunk, not the chain)
                self._slot_nodes = [None] * self.max_batch

    # ---- dense slot storage ------------------------------------------

    def _write_slot(self, slot: int, slot_caches, logits_row):
        """Scatter a batch-1 dense prefill result into a batch slot:
        scanned-block leaves are (nper, B, ...), leading dense-layer
        leaves are (B, ...) — the batch axis (1 and 0 respectively) is
        updated at ``slot``.  (Paged engines never prefill densely: the
        chunked path writes pages directly — see :meth:`_prefill_into_pages`.)
        """

        def upd(axis):
            return lambda big, small: jax.lax.dynamic_update_index_in_dim(
                big, jnp.squeeze(small, axis), slot, axis)

        new = {"blocks": {
            key: jax.tree.map(upd(1), big, slot_caches["blocks"][key])
            for key, big in self._slot_caches["blocks"].items()}}
        if "first" in self._slot_caches:
            new["first"] = [
                jax.tree.map(upd(0), big, slot_caches["first"][i])
                for i, big in enumerate(self._slot_caches["first"])]
        self._slot_caches = new
        self._slot_logits = self._slot_logits.at[slot].set(logits_row)

    # ---- paged slot storage: chunked prefill + copy-on-write ---------

    def _map_paged_caches(self, fn_pool, fn_row, *trees):
        """The single place that knows which slot-cache leaves are shared
        attention page *pools* and which are per-row state (recurrent /
        cross): apply ``fn_pool`` / ``fn_row`` leaf-wise across ``trees``
        (one tree transforms it, two zip-transform).  Both receive
        ``axis`` — the leaf group's batch/page axis: 1 inside scanned
        block stacks, 0 for the leading dense layers."""
        kinds, _ = transformer.period_spec(self.cfg)
        out = {"blocks": {}}
        for s, kind in enumerate(kinds):
            key = f"sub{s}"
            if key not in trees[0]["blocks"]:
                continue
            fn = fn_pool if kind in ("attn", "self") else fn_row
            out["blocks"][key] = jax.tree.map(
                lambda *ls, _fn=fn: _fn(1, *ls),
                *[t["blocks"][key] for t in trees])
        if "first" in trees[0]:
            fn = fn_pool if not getattr(self.cfg, "rwkv", False) else fn_row
            out["first"] = [
                jax.tree.map(lambda *ls, _fn=fn: _fn(0, *ls), *gs)
                for gs in zip(*[t["first"] for t in trees])]
        return out

    def _slice_row_caches(self, slot: int):
        """Batch-1 view of the slot caches for a chunk-prefill dispatch:
        attention page pools are batch-free and passed whole (the chunk
        writes only this request's pages + the dump page); per-row leaves
        (recurrent / cross state) are sliced to this row."""
        return self._map_paged_caches(
            lambda axis, leaf: leaf,
            lambda axis, leaf: jax.lax.dynamic_slice_in_dim(
                leaf, slot, 1, axis),
            self._slot_caches)

    def _merge_row_caches(self, slot: int, new):
        """Inverse of :meth:`_slice_row_caches`: adopt the (shared) pool
        leaves wholesale, scatter per-row leaves back into row ``slot``."""
        self._slot_caches = self._map_paged_caches(
            lambda axis, big, small: small,
            lambda axis, big, small: jax.lax.dynamic_update_slice_in_dim(
                big, small, slot, axis),
            self._slot_caches, new)

    def _alloc_pages(self, n: int):
        """Allocator alloc + quantized-pool hygiene: each page handed out
        gets its device scale rows zeroed (the allocator already zeroed
        its host mirror), so a reused page's running-max quantization
        starts fresh instead of inheriting the previous owner's absmax —
        which would silently coarsen every new write's quantum."""
        got = self._allocator.alloc(n)
        if got and self.kv_quant:
            for p in got:
                self._slot_caches = self._zero_scale(self._slot_caches,
                                                     jnp.int32(p))
        return got

    def _cow(self, slot: int, pidx: int, new_page: int):
        """Copy-on-write: duplicate the shared page at table index
        ``pidx`` into freshly-allocated ``new_page`` (every attention pool
        leaf — for a quantized pool that includes the per-page scale rows,
        so the copy dequantizes exactly like the original), drop this
        request's reference on the original, and remap the block table.
        The other holders keep the original untouched."""
        old = int(self._slot_tables[slot, pidx])
        self._slot_caches = self._cow_copy(
            self._slot_caches, jnp.int32(old), jnp.int32(new_page))
        self._allocator.copy_scale(old, new_page)
        self._allocator.free([old])
        self._slot_tables[slot, pidx] = new_page
        self._slot_pages[slot][pidx] = new_page
        self.cow_count += 1

    def _make_writable(self, slot: int, pidx: int) -> bool:
        """Ensure the page at ``pidx`` of this slot's table can be
        mutated: shared pages (refcount > 1) are COW-copied; a sole-owner
        page that is prefix-indexed just drops its (about-to-be-stale)
        cache entry.  Returns False when COW needs a page and the pool has
        none (the caller rolls back or preempts and retries)."""
        page = int(self._slot_tables[slot, pidx])
        if self._allocator.refcount(page) > 1:
            got = self._alloc_pages(1)
            if got is None:
                return False
            self._cow(slot, pidx, got[0])
        elif self._allocator.is_indexed(page):
            self._allocator.unindex(page)
        return True

    def _next_chunk(self, pos: int, plen: int,
                    budget: Optional[int]) -> tuple[int, int]:
        """Size the next prefill chunk at ``pos`` of a ``plen``-token
        context: returns ``(n, cap)`` — n real tokens dispatched at
        static capacity cap.  Caps come from a bounded set (page
        multiples up to ``prefill_chunk`` plus the one-page boundary
        chunk), so the chunk-prefill jit cache is keyed on
        O(prefill_chunk / page_size) shapes regardless of prompt lengths
        *or budget values*.  ``budget`` (the scheduler's remaining
        per-step tokens) trims mid-prompt chunks to whole pages — never
        below one page, so progress is guaranteed even when the budget is
        smaller than a page; ``None`` means unbudgeted (whole-prompt
        admission prefill)."""
        ps = self.page_size
        if pos % ps:
            # misaligned start (partial-page prefix hit; pad-safe only
            # — non-pad-safe archs never prefix-match): snap back to
            # the page grid with a one-page boundary chunk.  cap is
            # clamped so pos + cap never crosses max_len (the block
            # table's extent); padded positions past the allocated
            # span land in the dump page.
            return min(plen - pos, ps - pos % ps), \
                min(ps, self.max_len - pos)
        remaining = plen - pos
        if bool(getattr(self.cfg, "moe", False)):
            # splitting a routing batch perturbs capacity truncation:
            # one exact whole-prompt chunk
            return remaining, remaining
        want = remaining if budget is None \
            else min(remaining, max(budget, 1))
        n = min(self.prefill_chunk, want)
        if n < remaining:
            # mid-prompt chunk: a whole number of pages (at least one),
            # so every write lands page-aligned and the next chunk
            # resumes on the grid
            n = min(max(ps, n // ps * ps), remaining)
        cap = -(-n // ps) * ps if self._pad_safe_prefill else n
        return n, cap

    def _prefill_chunk_step(self, slot: int, ctx: list[int], pos: int,
                            n: int, cap: int) -> jnp.ndarray:
        """Dispatch one chunk of ``ctx[pos:pos + n]`` (static capacity
        ``cap``) through the TL chunk-prefill path, straight into this
        slot's pages.  The real-token count rides along as the runtime
        ``chunk_valid`` operand, so a padded tail's K/V never scatters
        into the pages — a pad write may not assume it owns the page tail
        once mid-flight dedup can hand that page to another request.
        Returns the chunk logits (caller gathers the last real row)."""
        toks = np.zeros((1, cap), np.int32)
        toks[0, :n] = ctx[pos:pos + n]
        bucket = self._decode_bucket(pos + cap)
        # .copy(): jax CPU zero-copies aligned contiguous numpy
        # buffers, and the dispatch is async — handing it the live
        # table would race with the next admission/COW/growth mutation
        # (whether a given allocation aliases is a malloc-alignment
        # accident, so the race is intermittent by process)
        tables = jnp.asarray(
            self._slot_tables[slot:slot + 1,
                              :bucket // self.page_size].copy())
        logits, new_caches = self._chunk_step(
            self.params, jnp.asarray(toks),
            self._slice_row_caches(slot),
            jnp.asarray([pos], np.int32), tables,
            jnp.asarray([n], np.int32), kv_bucket=bucket)
        self._merge_row_caches(slot, new_caches)
        self.prefill_tokens += n
        return logits

    def _prefill_into_pages(self, slot: int, ctx: list[int],
                            start: int) -> jnp.ndarray:
        """Chunked prefill of ``ctx[start:]`` straight into this slot's
        pages (the first ``start`` tokens came from the prefix cache).
        Chunks are ``prefill_chunk`` tokens; pad-safe architectures round
        the tail up to a page multiple (the padded positions are masked
        out of the page scatter by ``chunk_valid``) so compile count is
        bounded by chunk shapes, not prompt lengths.  Recurrent
        architectures keep exact-length tails (padding would contaminate
        state) and MoE architectures prefill one exact whole-prompt chunk
        (splitting a routing batch perturbs capacity truncation).
        Returns the next-token logits row (the last real position)."""
        plen = len(ctx)
        pos, logits, n = start, None, 0
        while pos < plen:
            n, cap = self._next_chunk(pos, plen, None)
            logits = self._prefill_chunk_step(slot, ctx, pos, n, cap)
            pos += n
        return logits[0, n - 1]

    def _preempt(self, req: Request):
        """Evict an active request: free its pages, requeue it for
        re-prefill (prompt + generated so far — no tokens are lost).
        Victims re-enter the queue ahead of fresh arrivals of their
        priority class, ordered by original admission ``seq`` — the
        :meth:`_queue_insert` sort keeps several victims preempted in one
        step in their relative admission order (a plain insert-at-front
        requeue put the latest victim first whenever an earlier victim
        was still waiting, starving the oldest)."""
        slot = req.slot
        self._allocator.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._slot_tables[slot, :] = self._dump_page
        self._slot_lens[slot] = 0
        self._slot_nodes[slot] = None
        self._active[slot] = None
        req.slot = -1
        req.pf_pos = req.pf_end = -1
        req.preempted = True
        self.preemptions += 1
        self._queue_insert(req)

    def _pick_victim(self) -> Optional[Request]:
        """Preemption victim: lowest priority class first, youngest
        admission (max ``seq``) within it — a background request is
        always evicted before a higher-priority one regardless of age.
        Returns None when the active set is empty: a sole active request
        can preempt *itself* (all its pages shared prefix hits free no
        allocatable page), after which victim selection must not blow up
        (``max()`` on the empty set raised ValueError here)."""
        cands = self.active_requests
        if not cands:
            return None
        return max(cands, key=lambda a: (-a.priority, a.seq))

    def _grow_pages(self):
        """Allocate-on-write: every active row whose next token starts a
        fresh page gets one before the decode writes it, and a row about
        to write mid-page is made exclusive first (COW if the page is
        shared through the prefix cache, un-indexing if it is the sole
        owner of a cached page).  On pool exhaustion the lowest-priority-
        then-youngest request is preempted (possibly the one asking)
        until the write can proceed — preempting a request whose pages
        are all shared frees no allocatable page, so the loop keeps
        preempting rather than declaring deadlock, and stops cleanly
        when the active set empties (:meth:`_pick_victim`).  Mid-prefill
        rows are skipped: budgeted admission allocated their pages up
        front and they take no decode write this step."""
        ps = self.page_size
        for r in list(self.active_requests):
            if self._active[r.slot] is not r:
                continue                     # preempted by an earlier row
            if r.prefilling:
                continue
            pos = int(self._slot_lens[r.slot])
            pidx = pos // ps
            if pos % ps:
                # mid-page write: the only shared pages a table can hold
                # mid-page are prefix-cache hits — make ours exclusive
                while self._active[r.slot] is r:
                    if self._make_writable(r.slot, pidx):
                        break
                    victim = self._pick_victim()
                    if victim is None:
                        break
                    self._preempt(victim)
                if self._active[r.slot] is r:
                    assert self._allocator.refcount(
                        int(self._slot_tables[r.slot, pidx])) == 1, \
                        "about to write a shared page"
                continue
            # page boundary: the previous page just filled — publish it to
            # the prefix cache, then allocate the write target
            if pidx and self.prefix_cache:
                # only chunk pidx-1 just filled; earlier pages were
                # registered at admission / previous boundaries, whose
                # resume handle makes this O(page_size), not O(pos)
                self._slot_nodes[r.slot] = self._allocator.register(
                    (r.prompt + r.tokens)[:pos], self._slot_pages[r.slot],
                    start=pidx - 1, resume=self._slot_nodes[r.slot])
            while self._active[r.slot] is r:
                got = self._alloc_pages(1)
                if got is not None:
                    self._slot_pages[r.slot].append(got[0])
                    self._slot_tables[r.slot, pidx] = got[0]
                    break
                victim = self._pick_victim()
                if victim is None:
                    break
                self._preempt(victim)

    # ---- speculative decode (draft -> verify -> rollback) -------------

    def _grow_spec_pages(self, r: Request, ntok: int) -> int:
        """Extend the slot's pages so up to ``ntok`` tokens (the committed
        token plus its drafts) can be written this step, and return how
        many actually fit.  :meth:`_grow_pages` already secured the page
        under the first write, so everything here is a fresh append —
        refcount-1, unindexed, trivially writable.  Pool pressure never
        preempts on behalf of a draft: speculative tokens are optional
        work, so exhaustion just truncates the proposal to what fits."""
        ps = self.page_size
        pos = int(self._slot_lens[r.slot])
        first = pos // ps
        room = (first + 1) * ps - pos     # slack in the secured page
        pidx = first + 1
        while room < ntok:
            got = self._alloc_pages(1)
            if got is None:
                break
            self._slot_pages[r.slot].append(got[0])
            self._slot_tables[r.slot, pidx] = got[0]
            pidx += 1
            room += ps
        return min(room, ntok)

    def _rollback_pages(self, slot: int, new_len: int) -> None:
        """Free the speculative pages past the accepted length: the slot
        keeps ``pages_for(new_len)`` pages, the tail goes back through
        the allocator's refcount machinery (a shared page is unreffed,
        never clobbered — rejected drafts only ever wrote pages this
        slot exclusively owned).  Rejected-draft K/V left in the *kept*
        tail page sits past ``new_len``, which every later read masks
        and the next decode overwrites."""
        keep = self._allocator.pages_for(new_len)
        dropped = self._slot_pages[slot][keep:]
        if not dropped:
            return
        self._allocator.free(dropped)
        self._slot_pages[slot] = self._slot_pages[slot][:keep]
        self._slot_tables[slot, keep:keep + len(dropped)] = self._dump_page
        self.rollback_pages += len(dropped)

    def _spec_step(self, active: list[Request], toks: np.ndarray,
                   finished: list[Request]) -> list[Request]:
        """Speculative tail of :meth:`step`: draft, verify once, commit
        the longest accepted prefix, roll the cache back.

        Every decode-phase row rides the one verify dispatch — a row with
        zero drafts (nothing proposed, temperature > 0, or no page room)
        is just a decode through the verify program (``chunk_valid=1``),
        so the zero-acceptance overhead is the K+1-wide query window, not
        an extra dispatch; a step where *no* row drafts falls back to the
        plain decode shape entirely, and the per-request throttle drives
        persistently rejected rows there.  Greedy acceptance: draft
        ``d_i`` commits iff
        it equals the argmax of the verify logits at the previous
        position — the committed stream is exactly what non-speculative
        greedy decode would have produced, token for token."""
        ps = self.page_size
        cap = self.draft_k + 1
        spec_toks = np.zeros((self.max_batch, cap), np.int32)
        spec_toks[:, 0] = toks
        valid = np.ones((self.max_batch,), np.int32)
        drafts: dict[int, list[int]] = {}
        for r in active:
            pos = int(self._slot_lens[r.slot])
            d: list[int] = []
            if r.temperature == 0.0:
                # per-request throttle: rejected drafts halve the allowed
                # length toward zero, a lone probe draft every
                # _SPEC_PROBE_PERIOD steps keeps the path able to recover
                allow = r.spec_k if r.spec_k >= 0 else self.draft_k
                if allow == 0 and (self._step_idx - r.submit_step) \
                        % _SPEC_PROBE_PERIOD == 0:
                    allow = 1
                # a draft past max_new_tokens or the cache capacity could
                # commit tokens the non-speculative engine never would
                limit = min(allow,
                            r.max_new_tokens - len(r.tokens),
                            self.max_len - 1 - pos)
                if limit > 0:
                    d = list(self._proposer.propose(
                        r.uid, r.prompt + r.tokens, limit))[:limit]
            if d:
                d = d[:self._grow_spec_pages(r, 1 + len(d)) - 1]
            drafts[r.slot] = d
            self.drafted_tokens += len(d)
            valid[r.slot] = 1 + len(d)
            spec_toks[r.slot, 1:1 + len(d)] = d

        if not any(drafts.values()):
            # nothing speculated anywhere this step (novel text, throttled
            # rows, temperature-only batch): the verify window would be
            # all padding, so take the plain decode dispatch — this is
            # what bounds the zero-acceptance overhead
            return self._decode_step(active, toks, finished)

        lens = self._slot_lens.copy()
        bucket = self._decode_bucket(
            min(int(lens.max()) + cap, self.max_len))
        tables_np = self._slot_tables[:, :bucket // ps].copy()
        for r in self.active_requests:
            if r.prefilling:
                tables_np[r.slot, :] = self._dump_page
        step_logits, self._slot_caches = self._run_verify(
            jnp.asarray(spec_toks), self._slot_caches,
            jnp.asarray(lens, np.int32), jnp.asarray(tables_np),
            jnp.asarray(valid), bucket)

        # longest accepted prefix per row: d_i commits iff it matches the
        # greedy token after position i-1; the next step's logits row is
        # the verify output at the last committed position
        pred = np.asarray(jnp.argmax(step_logits, axis=-1))
        accepted = np.zeros((self.max_batch,), np.int32)
        for r in active:
            d = drafts[r.slot]
            j = 0
            while j < len(d) and d[j] == int(pred[r.slot, j]):
                j += 1
            if d:
                self.accepted_tokens += j
                self._accept_rates.append(j / len(d))
                # throttle update: full acceptance restores the full
                # draft budget, partial acceptance tracks what landed,
                # total rejection quarters toward zero
                if j == len(d):
                    r.spec_k = self.draft_k
                elif j > 0:
                    r.spec_k = j
                else:
                    r.spec_k = (r.spec_k if r.spec_k >= 0
                                else self.draft_k) // 4
            accepted[r.slot] = j
            r.tokens.extend(d[:j])
            pos = int(self._slot_lens[r.slot])
            new_len = pos + 1 + j
            self._slot_lens[r.slot] = new_len
            self._rollback_pages(r.slot, new_len)
            if self.prefix_cache:
                # a multi-token commit can cross page boundaries between
                # the boundary-start publishes _grow_pages does — index
                # every newly filled page now (resume handle: O(new
                # chunks); re-registration is a no-op)
                full = new_len // ps
                if full:
                    ctx = (r.prompt + r.tokens)[:full * ps]
                    self._slot_nodes[r.slot] = self._allocator.register(
                        ctx, self._slot_pages[r.slot][:full],
                        resume=self._slot_nodes[r.slot])
        self._slot_logits = step_logits[
            jnp.arange(self.max_batch), jnp.asarray(accepted)]

        for r in active:
            if r.done or int(self._slot_lens[r.slot]) + 1 > self.max_len:
                self._stamp_finish(r)
                finished.append(r)
                self._retire(r)
        return finished

    # ---- admission ----------------------------------------------------

    def _admit(self):
        free = [i for i, r in enumerate(self._active) if r is None]
        while free and self._queue:
            req = self._queue[0]
            # a preempted request re-prefills prompt + generated tokens,
            # so admission cost is its full current context
            ctx = req.prompt + req.tokens
            plen = len(ctx)
            if plen >= self.max_len:
                # a preempted request re-admitted with a full cache has
                # nowhere to write its next token: retire it truncated at
                # max_len — the same rule step() applies to live slots
                self._queue.pop(0)
                self._stamp_finish(req)
                self._finished_early.append(req)
                continue
            if self.paged:
                need = self._allocator.pages_for(plen)
                if need > self._allocator.num_pages - 1:
                    # a preempted request whose context outgrew the whole
                    # pool can never be re-admitted: retire it truncated at
                    # pool capacity (the analogue of max_len truncation) so
                    # it cannot livelock itself and everything queued
                    # behind it
                    self._queue.pop(0)
                    self._stamp_finish(req)
                    self._finished_early.append(req)
                    continue
                # prefix-cache probe: map cached pages of the longest
                # matching prefix into this request's table instead of
                # recomputing them.  At least one token is always
                # recomputed — sampling needs next-token logits.
                matched, mlen = [], 0
                if self.prefix_cache:
                    matched, mlen = self._allocator.match_prefix(ctx)
                    mlen = min(mlen, plen - 1)
                    matched = matched[:self._allocator.pages_for(mlen)]
                self._allocator.ref(matched)
                fresh = self._alloc_pages(need - len(matched))
                if fresh is None:
                    self._allocator.free(matched)
                    break   # head-of-line waits for pages (FIFO preserved)
                pages = matched + fresh
                self._queue.pop(0)
                slot = free.pop(0)
                self._slot_tables[slot, :] = self._dump_page
                self._slot_tables[slot, :len(pages)] = pages
                self._slot_pages[slot] = pages
                # divergence mid-way through a shared page: make it ours
                # before the suffix prefill writes it (copy-on-write)
                if mlen % self.page_size \
                        and not self._make_writable(slot,
                                                    mlen // self.page_size):
                    # COW needs one more page and the pool is dry: roll
                    # back and wait (the sorted re-insert restores its
                    # head-of-line position, nothing leaked)
                    self._allocator.free(self._slot_pages[slot])
                    self._slot_pages[slot] = []
                    self._slot_tables[slot, :] = self._dump_page
                    self._queue_insert(req)
                    break
                # counted per *admitted* request, not per probe: a head-of-
                # line request blocked on pages re-probes every step, and
                # counting retries would make the lookup/hit pair lie
                if self.prefix_cache:
                    self.prefix_lookups += 1
                if mlen:
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += mlen
                if self._interleaved:
                    # budgeted admission: the slot, pages, and prefix hits
                    # are mapped now, but the prompt compute is deferred to
                    # _schedule_prefill, which spends the per-step token
                    # budget across all mid-prefill rows.  The row stays at
                    # length 0 (masked out of decode) until the last chunk
                    # lands.
                    self._slot_nodes[slot] = None
                    req.pf_pos, req.pf_end = mlen, plen
                    self._slot_lens[slot] = 0
                else:
                    logits_row = self._prefill_into_pages(slot, ctx, mlen)
                    if self.prefix_cache:
                        self._slot_nodes[slot] = self._allocator.register(
                            ctx, self._slot_pages[slot])
                    self._slot_logits = self._slot_logits.at[slot].set(
                        logits_row)
                    self._slot_lens[slot] = plen
            else:
                self._queue.pop(0)
                slot = free.pop(0)
                # batch-1 dense prefill scattered into the slot row.
                # Prompts are right-padded to a prompt bucket so the
                # prefill jit cache is bounded by O(log2 max_len) buckets,
                # not one trace per distinct prompt length — except where
                # padding perturbs the numerics (recurrent state /
                # capacity-truncated MoE), which prefill at the exact
                # length.
                pad_to = min(_bucket(plen, self.prompt_bucket_lo),
                             self.max_len) if self._pad_safe_prefill \
                    else plen
                toks = np.zeros((1, pad_to), np.int32)
                toks[0, :plen] = ctx
                caches = transformer.init_caches(self.cfg, 1, self.max_len)
                logits, caches = self._prefill(self.params,
                                               jnp.asarray(toks), caches)
                self._write_slot(slot, caches, logits[0, plen - 1])
                self._slot_lens[slot] = plen
            req.slot = slot
            req.seq = self._admit_seq
            self._admit_seq += 1
            req.preempted = False
            self._active[slot] = req

    # ---- budgeted chunked-prefill scheduling (SLO interleaving) -------

    @property
    def _interleaved(self) -> bool:
        """Budgeted chunked interleaving is active: a configured budget on
        a paged, pad-safe engine.  Recurrent state cannot ride a masked
        decode row and an MoE prompt is one indivisible routing batch, so
        both (and dense engines) keep whole-prompt admission."""
        return (self.prefill_budget is not None and self.paged
                and self._pad_safe_prefill)

    def _register_full_pages(self, r: Request) -> None:
        """Publish the chunks a mid-prefill request has fully written so
        far — as they land, not just at completion — so queued
        identical/shared-prefix prompts can dedup against a leader that
        is still prefilling.  The resume handle keeps each call
        O(new chunks), and re-registration of already-indexed chunks is a
        no-op (first writer wins)."""
        if not self.prefix_cache:
            return
        full = r.pf_pos // self.page_size
        if full == 0:
            return
        ctx = (r.prompt + r.tokens)[:full * self.page_size]
        self._slot_nodes[r.slot] = self._allocator.register(
            ctx, self._slot_pages[r.slot][:full],
            resume=self._slot_nodes[r.slot])

    def _adopt_shared_pages(self, r: Request) -> None:
        """Radix-style in-flight dedup: before computing the next chunk,
        re-probe the prefix index — a leader prefilling the same (or
        shared-prefix) prompt publishes full pages as it goes
        (:meth:`_register_full_pages`), and this follower maps them into
        its table instead of recomputing, returning its own fresh page
        for that chunk to the pool.  Adoption is whole-page and stops one
        token short of the prompt end: sampling needs next-token logits
        from a computed position, mirroring the admission-time
        ``mlen = min(mlen, plen - 1)`` truncation."""
        ps = self.page_size
        if not self.prefix_cache or r.pf_pos % ps:
            return
        k0 = r.pf_pos // ps
        kmax = (r.pf_end - 1) // ps
        if k0 >= kmax:
            return
        ctx = r.prompt + r.tokens
        pages, mlen = self._allocator.match_prefix(ctx)
        nfull = min(mlen // ps, kmax)
        k = k0
        while k < nfull:
            p, q = pages[k], self._slot_pages[r.slot][k]
            if p == q:
                # the index already maps our own page here (we published
                # it) — nothing to adopt for this chunk
                k += 1
                continue
            self._allocator.ref([p])
            self._allocator.free([q])   # fresh, unwritten: refcount 1 -> 0
            self._slot_pages[r.slot][k] = p
            self._slot_tables[r.slot, k] = p
            self.inflight_dedup_pages += 1
            self.prefix_hit_tokens += ps
            k += 1
        r.pf_pos = k * ps

    def _schedule_prefill(self) -> None:
        """Spend up to ``prefill_budget`` prompt tokens on chunk-prefill
        dispatches this step, highest priority first (admission ``seq``
        breaks ties), interleaved with — not ahead of — the decode batch.
        Chunks are whole pages mid-prompt, so the compile-count contract
        holds (caps are page multiples ≤ ``prefill_chunk``); the budget
        may overshoot by less than one chunk on the last dispatch because
        a chunk is indivisible.  A request whose final chunk lands joins
        the decode batch *this* step: its next-token logits are scattered
        into the slot-logits matrix before sampling runs."""
        budget = self.prefill_budget
        pf = [r for r in self.active_requests if r.prefilling]
        pf.sort(key=lambda r: (-r.priority, r.seq))
        for r in pf:
            while r.prefilling and budget > 0:
                self._adopt_shared_pages(r)
                ctx = r.prompt + r.tokens
                n, cap = self._next_chunk(r.pf_pos, r.pf_end, budget)
                logits = self._prefill_chunk_step(r.slot, ctx, r.pf_pos,
                                                  n, cap)
                r.pf_pos += n
                budget -= n
                self._register_full_pages(r)
                if not r.prefilling:        # prompt fully in cache
                    self._slot_lens[r.slot] = r.pf_end
                    self._slot_logits = self._slot_logits.at[r.slot].set(
                        logits[0, n - 1])
            if budget <= 0:
                break

    # ---- serving metrics ----------------------------------------------

    def _stamp_finish(self, r: Request) -> None:
        """Record a request's completion (normal retire, max_len retire,
        or capacity truncation) into the latency samples."""
        r.finish_time = time.perf_counter()
        r.finish_step = self._step_idx
        self._n_finished += 1
        self._n_generated += len(r.tokens)
        if r.first_token_time is not None and len(r.tokens) > 1:
            gaps = len(r.tokens) - 1
            self._tpot_s.append(
                (r.finish_time - r.first_token_time) / gaps)
            self._tpot_steps.append(
                (r.finish_step - r.first_token_step) / gaps)

    def stats(self) -> dict:
        """Snapshot of the engine-tracked serving metrics.

        ``ttft_*`` (time-to-first-token: submit -> first sampled token)
        and ``tpot_*`` (time-per-output-token: mean inter-token gap of a
        finished request with ≥ 2 tokens) each come as a percentile dict
        ``{n, p50, p99, mean}`` in wall seconds (``_s``) and in engine
        step counts (``_steps`` — deterministic, so tests and benchmark
        A/Bs can assert on them).  The remaining fields are the running
        counters (prefix cache, COW, dedup, preemptions, compiles)."""
        def pct(samples):
            if not samples:
                return {"n": 0, "p50": None, "p99": None, "mean": None}
            a = np.asarray(samples, np.float64)
            return {"n": int(a.size),
                    "p50": float(np.percentile(a, 50)),
                    "p99": float(np.percentile(a, 99)),
                    "mean": float(a.mean())}
        return {
            "steps": self._step_idx,
            "finished": self._n_finished,
            "generated_tokens": self._n_generated,
            "ttft_s": pct(self._ttft_s),
            "ttft_steps": pct(self._ttft_steps),
            "tpot_s": pct(self._tpot_s),
            "tpot_steps": pct(self._tpot_steps),
            "preemptions": self.preemptions,
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefill_tokens": self.prefill_tokens,
            "inflight_dedup_pages": self.inflight_dedup_pages,
            "cow_count": self.cow_count,
            "prefill_compiles": self.prefill_compiles,
            "decode_compiles": self.decode_compiles,
            "verify_compiles": self.verify_compiles,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "rollback_pages": self.rollback_pages,
            # per-verify-dispatch-per-row acceptance fraction (rows that
            # offered >= 1 draft); p50/p99 locate whether a mediocre mean
            # is uniform mediocrity or a bimodal hit-or-miss draft source
            "acceptance_rate": pct(self._accept_rates),
        }

    def reset_metrics(self) -> None:
        """Zero every *workload* metric :meth:`stats` reports — the
        latency samples, throughput totals, step counter, and the running
        serving counters (preemptions, prefix lookups/hits/hit-tokens,
        prefill tokens, COW copies, in-flight dedup pages, and the
        speculative-decode draft/accept/rollback tallies).  Exactly three
        fields survive, because they describe the *process*, not the
        workload: ``prefill_compiles``, ``decode_compiles``, and
        ``verify_compiles`` (with their jit caches) — benchmarks call
        this between a warm-up wave and a measured wave precisely so the
        measured wave reports zero fresh compiles.  Only call while the
        engine is drained (no queued or active requests): in-flight
        requests carry stamps relative to the old step counter."""
        self._step_idx = 0
        self._ttft_s, self._ttft_steps = [], []
        self._tpot_s, self._tpot_steps = [], []
        self._n_finished = 0
        self._n_generated = 0
        self.preemptions = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.rollback_pages = 0
        self._accept_rates = []
        # workload counters that leaked through resets until the bugfix
        # sweep: a warm-up wave's prefix/COW/prefill traffic inflated the
        # measured wave's numbers (hit *rates* computed from them were
        # silently wrong, not just large)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefill_tokens = 0
        self.inflight_dedup_pages = 0
        self.cow_count = 0

    def _retire(self, r: Request):
        """Release a request's slot and pages (it keeps its tokens)."""
        self._active[r.slot] = None
        self._slot_lens[r.slot] = 0
        if self.paged:
            self._allocator.free(self._slot_pages[r.slot])
            self._slot_pages[r.slot] = []
            self._slot_tables[r.slot, :] = self._dump_page
            self._slot_nodes[r.slot] = None

    def step(self) -> list[Request]:
        """One scheduler step: admit, (budgeted) prefill, decode.

        Admits queued requests into free slots first (paged engines also
        require pages for the prompt).  Under budgeted interleaving
        (``prefill_budget``) newly-admitted prompts then receive up to
        budget tokens of chunked prefill — a request whose final chunk
        lands joins the decode batch this same step; one still mid-prompt
        rides the decode masked at length 0 with its table row remapped
        to the dump page (its real pages must not take the masked row's
        dummy write).  Then one token is sampled per decode-phase
        request, the ones that are now done retire (their final token
        never needs to enter the cache), the rest decode as a batch
        (idle slots ride along masked at length 0, writing into the
        reserved dump page), and requests that hit max_len retire.
        Returns the requests that finished this step — including any that
        were truncated at pool capacity after a preemption.
        """
        self._ensure_slots()
        self._step_idx += 1
        self._admit()
        if self._interleaved:
            self._schedule_prefill()
        finished = self._finished_early
        self._finished_early = []
        active = [r for r in self.active_requests if not r.prefilling]
        if not active:
            return finished

        # one batched greedy pass for the whole slot matrix; only
        # temperature>0 requests pay for an individual sampling dispatch
        greedy = np.asarray(jnp.argmax(self._slot_logits, axis=-1))
        toks = np.zeros((self.max_batch,), np.int32)
        now = time.perf_counter()
        for r in active:
            if r.temperature > 0.0:
                tok, self._key = self._sample(self._slot_logits[r.slot],
                                              r.temperature, self._key)
                tok = int(np.asarray(tok))
            else:
                tok = int(greedy[r.slot])
            r.tokens.append(tok)
            toks[r.slot] = tok
            if len(r.tokens) == 1:
                r.first_token_time = now
                r.first_token_step = self._step_idx
                self._ttft_s.append(now - r.submit_time)
                self._ttft_steps.append(self._step_idx - r.submit_step)

        # retire requests their last sampled token just completed — before
        # page growth and decode, so a done request can neither be
        # preempted (which would re-generate past its limit) nor pay for a
        # cache write nobody will read
        still = []
        for r in active:
            if r.done:
                self._stamp_finish(r)
                finished.append(r)
                self._retire(r)
            else:
                still.append(r)
        active = still
        if not active:
            return finished

        if self.paged:
            # allocate this step's write pages; may preempt (the preempted
            # request keeps its sampled token and re-prefills later)
            self._grow_pages()
            active = [r for r in self.active_requests if not r.prefilling]
            if not active:
                return finished

        if self.spec_decode:
            # draft + single verify dispatch + rollback replaces the
            # decode dispatch below; token streams are bit-identical
            return self._spec_step(active, toks, finished)
        return self._decode_step(active, toks, finished)

    def _decode_step(self, active: list[Request], toks: np.ndarray,
                     finished: list[Request]) -> list[Request]:
        """Non-speculative tail of :meth:`step`: one batched decode
        dispatch, cache lengths advance by one.  Also the speculative
        path's fallback for steps where no row drafted anything — the
        verify window would be all padding, so the plain decode shape is
        strictly cheaper."""
        # idle slots decode a dummy token against a length-0 cache window;
        # their rows are garbage and never read back (paged: written to the
        # dump page)
        lens = self._slot_lens.copy()
        needed = int(lens.max()) + 1
        bucket = self._decode_bucket(needed)
        tables = None
        if self.paged:
            # .copy(): the decode is dispatched async and the next step's
            # _admit mutates slot tables before anything forces it; jax
            # CPU may zero-copy an aligned contiguous numpy buffer (when
            # bucket == max_len this slice is the whole table), which
            # would let the pending gather read the mutated rows
            tables_np = self._slot_tables[:, :bucket // self.page_size].copy()
            for r in self.active_requests:
                if r.prefilling:
                    # masked row, but its dummy write would land in the
                    # request's real page 0 — send it to the dump page
                    tables_np[r.slot, :] = self._dump_page
            tables = jnp.asarray(tables_np)
        step_logits, self._slot_caches = self._run_decode(
            jnp.asarray(toks)[:, None], self._slot_caches,
            jnp.asarray(lens, np.int32), tables, bucket)
        self._slot_logits = step_logits
        for r in active:
            self._slot_lens[r.slot] += 1

        for r in active:
            if self._slot_lens[r.slot] + 1 > self.max_len:
                self._stamp_finish(r)
                finished.append(r)
                self._retire(r)
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Drive :meth:`step` until queue and slots are empty.

        Raises ``RuntimeError`` if ``max_steps`` is exhausted while
        requests are still queued or active — partial progress is never
        silently dropped: the already-finished requests ride on the
        exception as ``err.finished``, and the un-finished ones keep their
        state on the engine (``active_requests`` / the queue), so a second
        call resumes where this one stopped."""
        done: list[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self._queue and not self.active_requests:
                return done
        pending = [r.uid for r in self._queue] \
            + [r.uid for r in self.active_requests]
        err = RuntimeError(
            f"run_until_drained: {len(pending)} request(s) still pending "
            f"after max_steps={max_steps} (uids {pending}); raise "
            "max_steps and call again — already-finished requests are on "
            "this exception's .finished, un-finished ones stay live on "
            "the engine")
        err.finished = done
        raise err
