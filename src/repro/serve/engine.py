"""Batched serving engine: prefill + bucketed runtime-length decode over a
paged KV cache.

The decode step is compiled per power-of-two *length bucket*, not per cache
length: ``cache_len`` is a traced per-request vector and the bucket (the
number of cache entries attention reads) is the only static shape input.
The jit cache is therefore bounded at O(log2(max_len)) decode entries
instead of one per generated token — the FlashDecoding-style serving
contract over the TL-generated runtime-length kernels.

KV storage for the ``submit()``/``step()`` path is *paged*: instead of one
dense ``(max_batch, Hkv, max_len, D)`` reservation per slot, every
attention layer owns a pool of fixed-size pages and a :class:`PageAllocator`
hands them out — ``ceil(len / page_size)`` pages per request, allocated on
write as the request grows and freed when it retires.  A request therefore
reserves HBM proportional to its *true* length, admitted-request capacity
is bounded by total pages rather than ``max_batch x max_len``, and the
per-row block table rides into the decode kernel as a runtime operand (the
TL paged-decode layout).  When the pool runs dry mid-decode the youngest
request is preempted — its pages are freed and it re-queues for
re-prefill — so neighbours' pages are never corrupted.

Prompt batches may be length-heterogeneous (attention-cache architectures):
prompts are right-padded to a shared bucket, next-token logits are gathered
at each request's true last position, and every downstream step masks the
cache at the per-request length.  Recurrent architectures (RWKV / Mamba
hybrids) carry state, so right-padding would contaminate it; batched
``generate`` keeps the homogeneous-length requirement for them, while the
``submit``/``step`` continuous-batching path prefills each request alone at
its exact length and so serves mixed lengths for every architecture.

``submit()``/``step()`` are the continuous-batching seam: requests are
admitted into free slots (gated on both a free slot *and* free pages) and
retired between decode steps while the rest of the batch keeps running.
The one-shot ``generate()`` path keeps the dense per-row cache — it admits
a whole batch at once and drops it at the end, so paging buys it nothing.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig


def _bucket(n: int, lo: int = 64) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class PageAllocator:
    """Free-list allocator over a fixed pool of KV-cache pages.

    Pages are the unit of HBM reservation: a request holds
    ``ceil(len / page_size)`` pages, so its reservation is O(true length)
    rather than O(max_len).  :meth:`alloc` is all-or-nothing — it returns
    ``None`` when the pool cannot satisfy the request, and the caller
    queues or preempts; a request is never given a partial allocation.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0:
            raise ValueError(f"num_pages {num_pages} must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free = list(range(self.num_pages - 1, -1, -1))  # LIFO

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` cache entries."""
        return -(-int(tokens) // self.page_size)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if not 0 <= p < self.num_pages or p in self._free:
                raise ValueError(f"double/invalid free of page {p}")
        self._free.extend(pages)


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray          # (B, new)
    prompt_len: list[int]
    steps: int


@dataclasses.dataclass
class Request:
    """One serving request moving through the continuous-batching loop."""

    uid: int
    prompt: list[int]
    max_new_tokens: int
    temperature: float = 0.0
    tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    seq: int = -1               # admission order (preemption picks max)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens


class ServeEngine:
    """Mesh-agnostic serving engine (pass ``shardings`` upstream via params).

    Compile accounting: ``prefill_compiles`` / ``decode_compiles`` count jit
    traces of the two step functions — the load-bearing guarantees are that
    ``decode_compiles`` stays ≤ the number of distinct length buckets
    touched (independent of how many tokens are generated), and
    ``prefill_compiles`` on the submit/step path stays ≤ the number of
    distinct *prompt buckets* touched (independent of how many distinct
    prompt lengths arrive).  Architectures where right-padding perturbs
    numerics — recurrent state, capacity-truncated MoE routing — prefill
    at the exact length and trace per distinct length instead.

    Paging: ``paged=True`` (the default for attention-cache architectures)
    stores the submit/step KV cache as page pools managed by a
    :class:`PageAllocator` — see the module docstring.  ``page_size`` must
    be a power of two ≤ the decode buckets and divide ``max_len``
    (validated when submit/step first materialise the pools — the dense
    ``generate()`` path has no such constraints); ``num_pages`` defaults to
    dense-capacity parity (``max_batch * max_len / page_size`` + the
    reserved dump page) — pass fewer to bound KV HBM below the dense
    reservation, at the cost of queueing/preemption under pressure.
    Architectures with no attention cache (pure RWKV/Mamba state) have
    nothing to page; ``paged`` silently turns off there.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 2048, vision_embeds=None,
                 decode_bucket_lo: int = 64, prompt_bucket_lo: int = 16,
                 paged: bool = True, page_size: int = 64,
                 num_pages: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.vision = vision_embeds
        self.decode_bucket_lo = decode_bucket_lo
        self.prompt_bucket_lo = prompt_bucket_lo
        # recurrent state (RWKV / Mamba hybrid) cannot be right-padded
        self.recurrent = bool(getattr(cfg, "rwkv", False)
                              or getattr(cfg, "hybrid_period", 0))
        # right-padding is numerics-preserving only when every layer is
        # per-token: recurrent state integrates the pad tokens, and
        # capacity-truncated MoE routing lets pad tokens displace real ones
        # from expert buffers — both prefill at the exact length instead
        # (one trace per distinct prompt length, documented trade-off)
        self._pad_safe_prefill = not (self.recurrent
                                      or bool(getattr(cfg, "moe", False)))
        kinds, _ = transformer.period_spec(cfg)
        has_attn_cache = any(k in ("attn", "self") for k in kinds) or (
            bool(cfg.first_k_dense) and not getattr(cfg, "rwkv", False))
        self.paged = bool(paged and has_attn_cache)
        self.page_size = int(page_size)
        # layout constraints are checked at first *paged* use (submit/step
        # materialise the pools) so generate()-only engines — which keep
        # the dense per-row cache — accept any max_len, as before
        self.num_pages = None if num_pages is None else int(num_pages)
        self.prefill_compiles = 0
        self.decode_compiles = 0

        def prefill(params, tokens, caches):
            self.prefill_compiles += 1          # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, tokens, cfg, caches=caches, cache_len=0,
                vision_embeds=self.vision)
            return logits, caches

        # cache_len is runtime data (a per-request vector); only the length
        # bucket — how many cache entries attention reads — is static, so
        # generating T tokens costs at most O(log2 max_len) decode traces.
        # ``tables`` is the paged path's block-table operand (None = dense).
        def decode(params, tok, caches, cache_len, tables, kv_bucket):
            self.decode_compiles += 1           # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, tok, cfg, caches=caches, cache_len=cache_len,
                kv_bucket=kv_bucket, block_tables=tables,
                page_size=self.page_size if tables is not None else None,
                vision_embeds=self.vision)
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, static_argnames=("kv_bucket",))

        # continuous-batching state (submit/step API)
        self._queue: list[Request] = []
        self._active: list[Optional[Request]] = []
        self._slot_caches = None
        self._slot_logits = None
        self._slot_lens: Optional[np.ndarray] = None
        self._allocator: Optional[PageAllocator] = None
        self._slot_tables: Optional[np.ndarray] = None
        self._slot_pages: list[list[int]] = []
        self._dump_page = 0
        self._next_uid = 0
        self._admit_seq = 0
        self._finished_early: list[Request] = []
        self._key = jax.random.PRNGKey(0)

    # ------------------------------------------------------------------
    # shared helpers
    # ------------------------------------------------------------------

    def _decode_bucket(self, needed: int) -> int:
        """Smallest power-of-two bucket covering ``needed`` cache entries
        (paged engines never go below one page)."""
        if needed > self.max_len:
            raise ValueError(f"cache length {needed} exceeds max_len "
                             f"{self.max_len}")
        lo = self.decode_bucket_lo
        if self.paged:
            lo = max(lo, self.page_size)
        return min(_bucket(needed, lo), self.max_len)

    def _sample(self, logits, temperature: float, key):
        """Returns (tokens, next_key).  The key is threaded explicitly so
        batched ``generate`` and the submit/step API keep independent
        sampling streams."""
        if temperature > 0.0:
            key, k2 = jax.random.split(key)
            return jax.random.categorical(k2, logits / temperature,
                                          axis=-1), key
        return jnp.argmax(logits, axis=-1), key

    # ------------------------------------------------------------------
    # batch generate (one-shot; heterogeneous prompt lengths allowed)
    # ------------------------------------------------------------------

    def generate(self, prompts: list[list[int]], *, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0) -> GenResult:
        """Greedy/temperature generation for a batch of prompts.

        Prompt lengths may differ (attention-cache architectures): the batch
        is right-padded to a shared bucket, per-request last-position logits
        seed decoding, and each request's cache length is tracked
        separately.  Recurrent architectures require homogeneous lengths
        here — use :meth:`submit`/:meth:`step` for mixed lengths there.
        This one-shot path keeps the dense per-row cache (see module
        docstring); the paged storage belongs to the submit/step loop.
        """
        if len(prompts) > self.max_batch:
            raise ValueError(f"batch {len(prompts)} > max_batch "
                             f"{self.max_batch}")
        b = len(prompts)
        lens = [len(p) for p in prompts]
        if max(lens) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({max(lens)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len {self.max_len}; raise max_len or shorten "
                "the request (step() truncates at capacity instead)")
        if self.recurrent and len(set(lens)) != 1:
            raise ValueError(
                "recurrent architectures carry state, so right-padded "
                "heterogeneous prefill would contaminate it; group "
                f"requests by prompt length (got {sorted(set(lens))})")
        # homogeneous batches prefill at the exact length (recurrent-safe
        # and numerically identical to a manual decode); heterogeneous
        # batches right-pad to a shared bucket and mask per request
        pad_to = lens[0] if len(set(lens)) == 1 else \
            min(_bucket(max(lens), self.prompt_bucket_lo), self.max_len)
        toks = np.zeros((b, pad_to), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p

        caches = transformer.init_caches(self.cfg, b, self.max_len)
        logits, caches = self._prefill(self.params, jnp.asarray(toks), caches)
        # next-token logits come from each prompt's true last position
        last = jnp.asarray([l - 1 for l in lens])
        step_logits = logits[jnp.arange(b), last]

        key = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new_tokens), np.int32)
        lens_v = np.asarray(lens, np.int32)
        for t in range(max_new_tokens):
            tok, key = self._sample(step_logits, temperature, key)
            out[:, t] = np.asarray(tok)
            bucket = self._decode_bucket(int(lens_v.max()) + 1)
            step_logits, caches = self._decode(
                self.params, tok[:, None].astype(jnp.int32), caches,
                jnp.asarray(lens_v), None, kv_bucket=bucket)
            lens_v = lens_v + 1
        return GenResult(tokens=out, prompt_len=lens, steps=max_new_tokens)

    # ------------------------------------------------------------------
    # continuous batching: submit / step
    # ------------------------------------------------------------------

    def submit(self, prompt: list[int], *, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        """Queue a request; it is admitted at the next :meth:`step`."""
        if self.vision is not None:
            raise ValueError(
                "submit()/step() admit requests one at a time, but "
                "vision_embeds are bound to the whole batch — use "
                "generate() for vision engines")
        if not prompt:
            raise ValueError("empty prompt: nothing to prefill")
        if len(prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(prompt)}) leaves no room to "
                             f"decode within max_len {self.max_len}")
        if self.paged:
            need = self._page_allocator().pages_for(len(prompt))
            if need > self._page_allocator().num_pages - 1:
                raise ValueError(
                    f"prompt needs {need} pages but the pool only has "
                    f"{self._page_allocator().num_pages - 1} allocatable "
                    "pages; raise num_pages")
        req = Request(uid=self._next_uid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, temperature=temperature)
        self._next_uid += 1
        self._queue.append(req)
        return req.uid

    @property
    def active_requests(self) -> list[Request]:
        return [r for r in self._active if r is not None]

    @property
    def allocator(self) -> Optional[PageAllocator]:
        """The page allocator (None until first step / for dense engines)."""
        return self._allocator

    def _page_allocator(self) -> PageAllocator:
        self._ensure_slots()
        return self._allocator

    def _ensure_slots(self):
        if self._slot_caches is None:
            if self.paged:
                if self.page_size & (self.page_size - 1):
                    raise ValueError(
                        f"page_size {self.page_size} must be a power of "
                        "two (decode buckets are powers of two)")
                if self.max_len % self.page_size:
                    raise ValueError(
                        f"max_len {self.max_len} must be a multiple of "
                        f"page_size {self.page_size} for the paged "
                        "submit/step path (generate() has no such "
                        "constraint)")
                if self.num_pages is None:
                    # dense-capacity parity + the reserved dump page
                    self.num_pages = self.max_batch * \
                        (self.max_len // self.page_size) + 1
            self._active = [None] * self.max_batch
            self._slot_caches = transformer.init_caches(
                self.cfg, self.max_batch, self.max_len, paged=self.paged,
                page_size=self.page_size,
                num_pages=self.num_pages if self.paged else None)
            self._slot_lens = np.zeros((self.max_batch,), np.int32)
            vocab = self.cfg.vocab_size
            self._slot_logits = jnp.zeros((self.max_batch, vocab),
                                          jnp.float32)
            if self.paged:
                self._allocator = PageAllocator(self.num_pages,
                                                self.page_size)
                # reserved dump page: idle slot rows' table entries point
                # here, so their ride-along decode writes can never land in
                # a live request's pages
                self._dump_page = self._allocator.alloc(1)[0]
                self._slot_tables = np.full(
                    (self.max_batch, self.max_len // self.page_size),
                    self._dump_page, np.int32)
                self._slot_pages = [[] for _ in range(self.max_batch)]

    # ---- paged slot storage ------------------------------------------

    def _scatter_prefill(self, pool, dense, pages: list[int], plen: int,
                         *, stacked: bool, latent: bool):
        """Write the first ``plen`` tokens of a batch-1 dense prefill cache
        into this request's pool ``pages`` — one scatter dispatch per leaf
        (not per page: pool-sized copies per page would make admission
        O(request_pages x pool_bytes)).

        ``stacked``: scanned-block leaves carry a leading ``nper`` axis.
        ``latent``: MLA pools are (P, ps, R+Rr); KV pools (P, Hkv, ps, D).
        """
        ps = self.page_size
        dn = dense[:, 0] if stacked else dense[0]   # drop the batch-1 axis
        # token axis of dn / (page, within-page) axes of the pool
        tok_ax = (1 if latent else 2) if stacked else (0 if latent else 1)
        page_ax = 1 if stacked else 0
        slot_ax = page_ax + (1 if latent else 2)
        # page-shape the true prefix: (npages, ps, rest...); the zero tail
        # of the last page lands in freshly-allocated rows nobody reads
        dn = jnp.moveaxis(dn, tok_ax, 0)[:plen]
        npg = len(pages)
        pad = npg * ps - plen
        if pad:
            dn = jnp.pad(dn, [(0, pad)] + [(0, 0)] * (dn.ndim - 1))
        dn = dn.reshape(npg, ps, *dn.shape[1:])
        pool_v = jnp.moveaxis(pool, (page_ax, slot_ax), (0, 1))
        pool_v = pool_v.at[jnp.asarray(pages, jnp.int32)].set(
            dn.astype(pool.dtype))
        return jnp.moveaxis(pool_v, (0, 1), (page_ax, slot_ax))

    def _write_slot(self, slot: int, slot_caches, logits_row, *,
                    pages: Optional[list[int]] = None, plen: int = 0):
        """Scatter a batch-1 prefill result into a batch slot.

        Dense layout: scanned-block leaves are (nper, B, ...), leading
        dense-layer leaves are (B, ...) — the batch axis (1 and 0
        respectively) is updated at ``slot``.  Paged layout: attention
        leaves are page pools, so the prefix is written into this request's
        ``pages`` instead; recurrent/cross state stays per-row.
        """
        kinds, _ = transformer.period_spec(self.cfg)

        def upd(axis):
            return lambda big, small: jax.lax.dynamic_update_index_in_dim(
                big, jnp.squeeze(small, axis), slot, axis)

        new_blocks = {}
        for s, kind in enumerate(kinds):
            key = f"sub{s}"
            if key not in self._slot_caches["blocks"]:
                continue
            big = self._slot_caches["blocks"][key]
            small = slot_caches["blocks"][key]
            if self.paged and kind in ("attn", "self"):
                new_blocks[key] = {
                    kk: self._scatter_prefill(big[kk], small[kk], pages,
                                              plen, stacked=True,
                                              latent=(kk == "c"))
                    for kk in big}
            else:
                new_blocks[key] = jax.tree.map(upd(1), big, small)
        new = {"blocks": new_blocks}
        if "first" in self._slot_caches:
            fk = "attn" if not getattr(self.cfg, "rwkv", False) else "rwkv"
            firsts = []
            for i, big in enumerate(self._slot_caches["first"]):
                small = slot_caches["first"][i]
                if self.paged and fk == "attn":
                    firsts.append({
                        kk: self._scatter_prefill(big[kk], small[kk], pages,
                                                  plen, stacked=False,
                                                  latent=(kk == "c"))
                        for kk in big})
                else:
                    firsts.append(jax.tree.map(upd(0), big, small))
            new["first"] = firsts
        self._slot_caches = new
        self._slot_logits = self._slot_logits.at[slot].set(logits_row)

    def _preempt(self, req: Request):
        """Evict an active request: free its pages, requeue it at the front
        for re-prefill (prompt + generated so far — no tokens are lost)."""
        slot = req.slot
        self._allocator.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self._slot_tables[slot, :] = self._dump_page
        self._slot_lens[slot] = 0
        self._active[slot] = None
        req.slot = -1
        self._queue.insert(0, req)

    def _grow_pages(self):
        """Allocate-on-write: every active row whose next token starts a
        fresh page gets one before the decode writes it.  On pool
        exhaustion the youngest-admitted request is preempted (possibly the
        one asking) until the write can proceed."""
        for r in list(self.active_requests):
            if self._active[r.slot] is not r:
                continue                     # preempted by an earlier row
            pos = int(self._slot_lens[r.slot])
            if pos % self.page_size:
                continue                     # current page still has room
            pidx = pos // self.page_size
            while self._active[r.slot] is r:
                got = self._allocator.alloc(1)
                if got is not None:
                    self._slot_pages[r.slot].append(got[0])
                    self._slot_tables[r.slot, pidx] = got[0]
                    break
                before = self._allocator.free_pages
                self._preempt(max(self.active_requests,
                                  key=lambda a: a.seq))
                if self._allocator.free_pages == before:  # pragma: no cover
                    raise RuntimeError("page pool deadlock: preemption "
                                       "freed no pages")

    # ---- admission ----------------------------------------------------

    def _admit(self):
        free = [i for i, r in enumerate(self._active) if r is None]
        while free and self._queue:
            req = self._queue[0]
            # a preempted request re-prefills prompt + generated tokens,
            # so admission cost is its full current context
            ctx = req.prompt + req.tokens
            plen = len(ctx)
            if plen >= self.max_len:
                # a preempted request re-admitted with a full cache has
                # nowhere to write its next token: retire it truncated at
                # max_len — the same rule step() applies to live slots
                self._queue.pop(0)
                self._finished_early.append(req)
                continue
            pages = None
            if self.paged:
                need = self._allocator.pages_for(plen)
                if need > self._allocator.num_pages - 1:
                    # a preempted request whose context outgrew the whole
                    # pool can never be re-admitted: retire it truncated at
                    # pool capacity (the analogue of max_len truncation) so
                    # it cannot livelock itself and everything queued
                    # behind it
                    self._queue.pop(0)
                    self._finished_early.append(req)
                    continue
                pages = self._allocator.alloc(need)
                if pages is None:
                    break   # head-of-line waits for pages (FIFO preserved)
            self._queue.pop(0)
            slot = free.pop(0)
            # batch-1 prefill scattered into the slot row.  Prompts are
            # right-padded to a prompt bucket so the prefill jit cache is
            # bounded by O(log2 max_len) buckets, not one trace per
            # distinct prompt length — except where padding perturbs the
            # numerics (recurrent state / capacity-truncated MoE), which
            # prefill at the exact length.
            pad_to = min(_bucket(plen, self.prompt_bucket_lo),
                         self.max_len) if self._pad_safe_prefill else plen
            toks = np.zeros((1, pad_to), np.int32)
            toks[0, :plen] = ctx
            # paged slots copy only the true prefix out of the prefill
            # cache, so the transient buffer can be bucket-sized; dense
            # slots are written by a whole-buffer row update
            cap = pad_to if self.paged else self.max_len
            caches = transformer.init_caches(self.cfg, 1, cap)
            logits, caches = self._prefill(self.params, jnp.asarray(toks),
                                           caches)
            if self.paged:
                self._slot_tables[slot, :] = self._dump_page
                self._slot_tables[slot, :len(pages)] = pages
                self._slot_pages[slot] = pages
            self._write_slot(slot, caches, logits[0, plen - 1],
                             pages=pages, plen=plen)
            self._slot_lens[slot] = plen
            req.slot = slot
            req.seq = self._admit_seq
            self._admit_seq += 1
            self._active[slot] = req

    def _retire(self, r: Request):
        """Release a request's slot and pages (it keeps its tokens)."""
        self._active[r.slot] = None
        self._slot_lens[r.slot] = 0
        if self.paged:
            self._allocator.free(self._slot_pages[r.slot])
            self._slot_pages[r.slot] = []
            self._slot_tables[r.slot, :] = self._dump_page

    def step(self) -> list[Request]:
        """One decode step for every active slot.

        Admits queued requests into free slots first (paged engines also
        require pages for the prompt), samples one token per active
        request, retires the ones that are now done (their final token
        never needs to enter the cache), then decodes the rest as a batch
        (idle slots ride along masked at length 0, writing into the
        reserved dump page) and retires requests that hit max_len.
        Returns the requests that finished this step — including any that
        were truncated at pool capacity after a preemption.
        """
        self._ensure_slots()
        self._admit()
        finished = self._finished_early
        self._finished_early = []
        active = self.active_requests
        if not active:
            return finished

        # one batched greedy pass for the whole slot matrix; only
        # temperature>0 requests pay for an individual sampling dispatch
        greedy = np.asarray(jnp.argmax(self._slot_logits, axis=-1))
        toks = np.zeros((self.max_batch,), np.int32)
        for r in active:
            if r.temperature > 0.0:
                tok, self._key = self._sample(self._slot_logits[r.slot],
                                              r.temperature, self._key)
                tok = int(np.asarray(tok))
            else:
                tok = int(greedy[r.slot])
            r.tokens.append(tok)
            toks[r.slot] = tok

        # retire requests their last sampled token just completed — before
        # page growth and decode, so a done request can neither be
        # preempted (which would re-generate past its limit) nor pay for a
        # cache write nobody will read
        still = []
        for r in active:
            if r.done:
                finished.append(r)
                self._retire(r)
            else:
                still.append(r)
        active = still
        if not active:
            return finished

        if self.paged:
            # allocate this step's write pages; may preempt (the preempted
            # request keeps its sampled token and re-prefills later)
            self._grow_pages()
            active = self.active_requests
            if not active:
                return finished

        # idle slots decode a dummy token against a length-0 cache window;
        # their rows are garbage and never read back (paged: written to the
        # dump page)
        lens = self._slot_lens.copy()
        needed = int(lens.max()) + 1
        bucket = self._decode_bucket(needed)
        tables = None
        if self.paged:
            tables = jnp.asarray(
                self._slot_tables[:, :bucket // self.page_size])
        step_logits, self._slot_caches = self._decode(
            self.params, jnp.asarray(toks)[:, None], self._slot_caches,
            jnp.asarray(lens, np.int32), tables, kv_bucket=bucket)
        self._slot_logits = step_logits
        for r in active:
            self._slot_lens[r.slot] += 1

        for r in active:
            if self._slot_lens[r.slot] + 1 > self.max_len:
                finished.append(r)
                self._retire(r)
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Drive :meth:`step` until queue and slots are empty.

        Raises ``RuntimeError`` if ``max_steps`` is exhausted while
        requests are still queued or active — partial progress is never
        silently dropped: the already-finished requests ride on the
        exception as ``err.finished``, and the un-finished ones keep their
        state on the engine (``active_requests`` / the queue), so a second
        call resumes where this one stopped."""
        done: list[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self._queue and not self.active_requests:
                return done
        pending = [r.uid for r in self._queue] \
            + [r.uid for r in self.active_requests]
        err = RuntimeError(
            f"run_until_drained: {len(pending)} request(s) still pending "
            f"after max_steps={max_steps} (uids {pending}); raise "
            "max_steps and call again — already-finished requests are on "
            "this exception's .finished, un-finished ones stay live on "
            "the engine")
        err.finished = done
        raise err
