"""Batched serving engine: prefill + bucketed runtime-length decode.

The decode step is compiled per power-of-two *length bucket*, not per cache
length: ``cache_len`` is a traced per-request vector and the bucket (the
number of cache entries attention reads) is the only static shape input.
The jit cache is therefore bounded at O(log2(max_len)) decode entries
instead of one per generated token — the FlashDecoding-style serving
contract over the TL-generated runtime-length kernels.

Prompt batches may be length-heterogeneous (attention-cache architectures):
prompts are right-padded to a shared bucket, next-token logits are gathered
at each request's true last position, and every downstream step masks the
cache at the per-request length.  Recurrent architectures (RWKV / Mamba
hybrids) carry state, so right-padding would contaminate it; batched
``generate`` keeps the homogeneous-length requirement for them, while the
``submit``/``step`` continuous-batching path prefills each request alone at
its exact length and so serves mixed lengths for every architecture.

``submit()``/``step()`` are the continuous-batching seam: requests are
admitted into free slots and retired between decode steps while the rest
of the batch keeps running.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..models.config import ModelConfig


def _bucket(n: int, lo: int = 64) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class GenResult:
    tokens: np.ndarray          # (B, new)
    prompt_len: list[int]
    steps: int


@dataclasses.dataclass
class Request:
    """One serving request moving through the continuous-batching loop."""

    uid: int
    prompt: list[int]
    max_new_tokens: int
    temperature: float = 0.0
    tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens


class ServeEngine:
    """Mesh-agnostic serving engine (pass ``shardings`` upstream via params).

    Compile accounting: ``prefill_compiles`` / ``decode_compiles`` count jit
    traces of the two step functions — the load-bearing guarantee is that
    ``decode_compiles`` stays ≤ the number of distinct length buckets
    touched, independent of how many tokens are generated.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 2048, vision_embeds=None,
                 decode_bucket_lo: int = 64, prompt_bucket_lo: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.vision = vision_embeds
        self.decode_bucket_lo = decode_bucket_lo
        self.prompt_bucket_lo = prompt_bucket_lo
        # recurrent state (RWKV / Mamba hybrid) cannot be right-padded
        self.recurrent = bool(getattr(cfg, "rwkv", False)
                              or getattr(cfg, "hybrid_period", 0))
        self.prefill_compiles = 0
        self.decode_compiles = 0

        def prefill(params, tokens, caches):
            self.prefill_compiles += 1          # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, tokens, cfg, caches=caches, cache_len=0,
                vision_embeds=self.vision)
            return logits, caches

        # cache_len is runtime data (a per-request vector); only the length
        # bucket — how many cache entries attention reads — is static, so
        # generating T tokens costs at most O(log2 max_len) decode traces.
        def decode(params, tok, caches, cache_len, kv_bucket):
            self.decode_compiles += 1           # runs once per jit trace
            logits, _, caches = transformer.apply(
                params, tok, cfg, caches=caches, cache_len=cache_len,
                kv_bucket=kv_bucket, vision_embeds=self.vision)
            return logits[:, -1], caches

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, static_argnames=("kv_bucket",))

        # continuous-batching state (submit/step API)
        self._queue: list[Request] = []
        self._active: list[Optional[Request]] = []
        self._slot_caches = None
        self._slot_logits = None
        self._slot_lens: Optional[np.ndarray] = None
        self._next_uid = 0
        self._key = jax.random.PRNGKey(0)

    # ------------------------------------------------------------------
    # shared helpers
    # ------------------------------------------------------------------

    def _decode_bucket(self, needed: int) -> int:
        """Smallest power-of-two bucket covering ``needed`` cache entries."""
        if needed > self.max_len:
            raise ValueError(f"cache length {needed} exceeds max_len "
                             f"{self.max_len}")
        return min(_bucket(needed, self.decode_bucket_lo), self.max_len)

    def _sample(self, logits, temperature: float, key):
        """Returns (tokens, next_key).  The key is threaded explicitly so
        batched ``generate`` and the submit/step API keep independent
        sampling streams."""
        if temperature > 0.0:
            key, k2 = jax.random.split(key)
            return jax.random.categorical(k2, logits / temperature,
                                          axis=-1), key
        return jnp.argmax(logits, axis=-1), key

    # ------------------------------------------------------------------
    # batch generate (one-shot; heterogeneous prompt lengths allowed)
    # ------------------------------------------------------------------

    def generate(self, prompts: list[list[int]], *, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0) -> GenResult:
        """Greedy/temperature generation for a batch of prompts.

        Prompt lengths may differ (attention-cache architectures): the batch
        is right-padded to a shared bucket, per-request last-position logits
        seed decoding, and each request's cache length is tracked
        separately.  Recurrent architectures require homogeneous lengths
        here — use :meth:`submit`/:meth:`step` for mixed lengths there.
        """
        if len(prompts) > self.max_batch:
            raise ValueError(f"batch {len(prompts)} > max_batch "
                             f"{self.max_batch}")
        b = len(prompts)
        lens = [len(p) for p in prompts]
        if max(lens) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({max(lens)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len {self.max_len}; raise max_len or shorten "
                "the request (step() truncates at capacity instead)")
        if self.recurrent and len(set(lens)) != 1:
            raise ValueError(
                "recurrent architectures carry state, so right-padded "
                "heterogeneous prefill would contaminate it; group "
                f"requests by prompt length (got {sorted(set(lens))})")
        # homogeneous batches prefill at the exact length (recurrent-safe
        # and numerically identical to a manual decode); heterogeneous
        # batches right-pad to a shared bucket and mask per request
        pad_to = lens[0] if len(set(lens)) == 1 else \
            min(_bucket(max(lens), self.prompt_bucket_lo), self.max_len)
        toks = np.zeros((b, pad_to), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p

        caches = transformer.init_caches(self.cfg, b, self.max_len)
        logits, caches = self._prefill(self.params, jnp.asarray(toks), caches)
        # next-token logits come from each prompt's true last position
        last = jnp.asarray([l - 1 for l in lens])
        step_logits = logits[jnp.arange(b), last]

        key = jax.random.PRNGKey(seed)
        out = np.zeros((b, max_new_tokens), np.int32)
        lens_v = np.asarray(lens, np.int32)
        for t in range(max_new_tokens):
            tok, key = self._sample(step_logits, temperature, key)
            out[:, t] = np.asarray(tok)
            bucket = self._decode_bucket(int(lens_v.max()) + 1)
            step_logits, caches = self._decode(
                self.params, tok[:, None].astype(jnp.int32), caches,
                jnp.asarray(lens_v), kv_bucket=bucket)
            lens_v = lens_v + 1
        return GenResult(tokens=out, prompt_len=lens, steps=max_new_tokens)

    # ------------------------------------------------------------------
    # continuous batching: submit / step
    # ------------------------------------------------------------------

    def submit(self, prompt: list[int], *, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        """Queue a request; it is admitted at the next :meth:`step`."""
        if self.vision is not None:
            raise ValueError(
                "submit()/step() admit requests one at a time, but "
                "vision_embeds are bound to the whole batch — use "
                "generate() for vision engines")
        req = Request(uid=self._next_uid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, temperature=temperature)
        self._next_uid += 1
        self._queue.append(req)
        return req.uid

    @property
    def active_requests(self) -> list[Request]:
        return [r for r in self._active if r is not None]

    def _ensure_slots(self):
        if self._slot_caches is None:
            self._active = [None] * self.max_batch
            self._slot_caches = transformer.init_caches(
                self.cfg, self.max_batch, self.max_len)
            self._slot_lens = np.zeros((self.max_batch,), np.int32)
            vocab = self.cfg.vocab_size
            self._slot_logits = jnp.zeros((self.max_batch, vocab),
                                          jnp.float32)

    def _write_slot(self, slot: int, slot_caches, logits_row):
        """Scatter a batch-1 prefill result into a batch slot.

        Cache layout: scanned-block leaves are (nper, B, ...), leading
        dense-layer leaves are (B, ...) — the batch axis is 1 and 0
        respectively."""
        def upd(axis):
            return lambda big, small: jax.lax.dynamic_update_index_in_dim(
                big, jnp.squeeze(small, axis), slot, axis)
        new = {"blocks": jax.tree.map(upd(1), self._slot_caches["blocks"],
                                      slot_caches["blocks"])}
        if "first" in self._slot_caches:
            new["first"] = jax.tree.map(upd(0), self._slot_caches["first"],
                                        slot_caches["first"])
        self._slot_caches = new
        self._slot_logits = self._slot_logits.at[slot].set(logits_row)

    def _admit(self):
        free = [i for i, r in enumerate(self._active) if r is None]
        while free and self._queue:
            req = self._queue.pop(0)
            slot = free.pop(0)
            # exact-length batch-1 prefill (recurrent-safe), scattered into
            # the slot row; jit cache grows per distinct prompt length —
            # round to a prompt bucket upstream if that matters
            toks = jnp.asarray([req.prompt], jnp.int32)
            caches = transformer.init_caches(self.cfg, 1, self.max_len)
            logits, caches = self._prefill(self.params, toks, caches)
            self._write_slot(slot, caches, logits[0, len(req.prompt) - 1])
            self._slot_lens[slot] = len(req.prompt)
            req.slot = slot
            self._active[slot] = req

    def step(self) -> list[Request]:
        """One decode step for every active slot.

        Admits queued requests into free slots first, then decodes one
        token for the whole batch (idle slots ride along masked at length
        1), and retires finished requests.  Returns the requests that
        finished this step.
        """
        self._ensure_slots()
        self._admit()
        active = self.active_requests
        if not active:
            return []

        # one batched greedy pass for the whole slot matrix; only
        # temperature>0 requests pay for an individual sampling dispatch
        greedy = np.asarray(jnp.argmax(self._slot_logits, axis=-1))
        toks = np.zeros((self.max_batch,), np.int32)
        for r in active:
            if r.temperature > 0.0:
                tok, self._key = self._sample(self._slot_logits[r.slot],
                                              r.temperature, self._key)
                tok = int(np.asarray(tok))
            else:
                tok = int(greedy[r.slot])
            r.tokens.append(tok)
            toks[r.slot] = tok

        # idle slots decode a dummy token against a length-1 cache window;
        # their rows are garbage and never read back
        lens = self._slot_lens.copy()
        needed = int(lens.max()) + 1
        bucket = self._decode_bucket(needed)
        step_logits, self._slot_caches = self._decode(
            self.params, jnp.asarray(toks)[:, None], self._slot_caches,
            jnp.asarray(lens, np.int32), kv_bucket=bucket)
        self._slot_logits = step_logits
        for r in active:
            self._slot_lens[r.slot] += 1

        finished = []
        for r in active:
            if r.done or self._slot_lens[r.slot] + 1 > self.max_len:
                finished.append(r)
                self._active[r.slot] = None
                self._slot_lens[r.slot] = 0
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        """Drive :meth:`step` until queue and slots are empty."""
        done: list[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self._queue and not self.active_requests:
                break
        return done
