from .engine import PageAllocator, ServeEngine  # noqa: F401
