from .draft import DraftProposer, NgramProposer, make_proposer  # noqa: F401
from .engine import PageAllocator, ServeEngine  # noqa: F401
